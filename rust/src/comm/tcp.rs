//! The multi-process TCP cluster backend (DESIGN.md §9, §14).
//!
//! One coordinator process drives `m` worker processes over loopback or
//! a real network. Each worker hosts one machine's state — as
//! `local_threads` sub-shard [`WorkerState`]s built locally from a
//! [`ProblemSpec`], so for synthetic data **no training examples cross
//! the wire** — and executes the same fused broadcast-apply +
//! local-step round the in-process backends run, with its sub-solvers
//! on real threads and their sub-deltas merged machine-locally
//! (DESIGN.md §10), returning the one `Δv_ℓ` message the coordinator's
//! tree-reduce consumes. Because floats travel as raw bit patterns and
//! every per-machine quantity (partition, RNG stream, batch size) is
//! derived from shared seeds, a TCP solve is **bit-identical** to a
//! `Cluster::Serial` solve of the same problem and `(m, T)` layout.
//!
//! Handshake (see [`Frame`]):
//!
//! ```text
//! worker                     coordinator
//!   | -- Hello{magic,ver} ----> |   accept order = machine index
//!   | <-- Welcome{ver,l,m} ---- |   (mismatch ⇒ Error frame + Err)
//!   | <-- AssignPartition ----- |
//!   | --- Ack ---------------->  |
//! ```
//!
//! Failure semantics (DESIGN.md §14): every fallible operation returns a
//! typed [`CommError`] — never a panic, never a hang. Connections run
//! under a liveness regime ([`FaultTolerance`]): socket reads time out
//! every `heartbeat_every`, each expiry probes the worker with a
//! `Heartbeat` frame (a dead route fails the probe write immediately),
//! and a worker that produces no frame within `worker_timeout` is
//! *declared dead*. A declared-dead worker either surfaces as a typed
//! [`CommError::WorkerFault`] (resurrection disabled or budget
//! exhausted) or is deterministically **resurrected**: the coordinator
//! re-listens on its retained listener, re-admits a replacement process
//! via the `Rejoin` handshake — re-shipping the dead machine's original
//! [`ProblemSpec`] plus the replay log of every state-mutating frame it
//! had fully processed and the coordinator's shadow ṽ replica as a
//! bitwise determinism cross-check — then resends the not-yet-retired
//! in-flight frames in FIFO order, so the solve's trace is
//! bit-identical to an uninterrupted run. Workers exit cleanly on
//! `Shutdown` or on coordinator disconnect.
//!
//! The coordinator records **actual wire bytes** (header + payload, both
//! directions) in [`WireStats`]; `Dadm::wire_bytes` surfaces them so the
//! `sparse_comm` α-β cost model can be validated against real traffic.

use std::collections::VecDeque;
use std::io::{BufReader, BufWriter, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::allreduce::tree_sum;
use super::cluster::run_subgroup;
use super::error::{CommError, CommResult};
use super::sparse::{compress_delta, tree_allreduce_delta, Delta, DeltaCodec};
use super::wire::{
    shard_data_spec, write_broadcast, write_eval, write_local_step, BroadcastRef, DataSpec,
    EvalOp, Frame, ProblemSpec, StepFlags, WireBroadcast, WireError, WireLoss, WireReg,
    WireSolver, FRAME_HEADER_BYTES, MAX_FRAME_LEN, WIRE_MAGIC, WIRE_VERSION,
};
use crate::data::partition::{split_nnz, split_ranges};
use crate::data::{Balance, Dataset, Partition};
use crate::solver::{batch_size, machine_rngs, run_fused_step, WorkerState};
use crate::utils::Rng;

/// A worker-attributed fault: the transport (or the worker itself)
/// failed in a way tied to machine `l`.
fn fault(l: usize, message: String) -> CommError {
    CommError::WorkerFault {
        id: l as u32,
        message,
    }
}

/// A protocol/usage error with no particular worker to blame
/// (mis-sized spec lists, unexpected frame kinds during negotiation).
fn proto(message: String) -> CommError {
    CommError::Decode(WireError::Malformed(message))
}

/// Worker-side `bail!`: hosted computation reports failures as plain
/// rendered strings — [`serve`] ships them verbatim in a
/// [`Frame::Error`], and the coordinator re-types them as
/// [`CommError::WorkerFault`].
macro_rules! wbail {
    ($($arg:tt)*) => {
        return Err(format!($($arg)*))
    };
}

/// Worker-side `ensure!` over [`wbail!`].
macro_rules! wensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            wbail!($($arg)*);
        }
    };
}

/// Liveness + resurrection policy for one cluster (DESIGN.md §14;
/// `--worker-timeout` / `--heartbeat-every` / `--max-rejoins`).
///
/// `worker_timeout` bounds one *logical* receive: a worker that
/// produces no frame for that long is declared dead, so it must exceed
/// the longest compute leg (plus, under resurrection, the replay time
/// of a rejoining worker). `heartbeat_every` is the probe cadence —
/// each expiry of the socket read timeout sends one `Heartbeat`, so a
/// dead *route* (as opposed to a dead process, which surfaces instantly
/// as EOF/RST) fails the probe write well before the deadline.
/// `max_rejoins = 0` disables resurrection: death surfaces as a typed
/// [`CommError::WorkerFault`] instead.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultTolerance {
    /// Declare a worker dead after this long without a frame.
    pub worker_timeout: Duration,
    /// Probe cadence while waiting (also the socket read timeout).
    pub heartbeat_every: Duration,
    /// How many worker deaths may be healed by resurrection (0 = none).
    pub max_rejoins: u32,
}

impl Default for FaultTolerance {
    fn default() -> Self {
        FaultTolerance {
            worker_timeout: Duration::from_secs(30),
            heartbeat_every: Duration::from_secs(5),
            max_rejoins: 0,
        }
    }
}

/// Cumulative transport counters (coordinator side; bytes include the
/// 5-byte frame header).
#[derive(Clone, Copy, Debug, Default)]
pub struct WireStats {
    /// Bytes written to workers.
    pub bytes_sent: u64,
    /// Bytes read from workers.
    pub bytes_received: u64,
    /// Frames written to workers.
    pub frames_sent: u64,
    /// Frames read from workers.
    pub frames_received: u64,
    /// Bytes of received `DeltaReply` frames (header included) — the
    /// reduce leg's actual traffic, which the compression acceptance
    /// gate compares across codecs (DESIGN.md §13).
    pub delta_reply_bytes: u64,
}

impl WireStats {
    /// Total bytes moved in either direction.
    pub fn total_bytes(&self) -> u64 {
        self.bytes_sent + self.bytes_received
    }
}

/// An outsized one-off frame (a shard-carrying AssignPartition can
/// legally approach [`MAX_FRAME_LEN`]) must not pin its payload size
/// for a connection's lifetime; steady-state frames sit far below this
/// cap, so the scratch reuse is undisturbed.
const MAX_RETAINED_PAYLOAD: usize = 1 << 20;

/// One framed, buffered, byte-counted connection. The encode and
/// payload-read scratch buffers persist for the connection's lifetime,
/// so the per-message hot path allocates no fresh frame `Vec`s.
/// With `liveness` set (coordinator side), receives run under the §14
/// deadline/heartbeat regime instead of blocking indefinitely.
struct Framed {
    r: BufReader<TcpStream>,
    w: BufWriter<TcpStream>,
    /// §14 liveness regime; `None` blocks indefinitely (worker side).
    liveness: Option<FaultTolerance>,
    sent: u64,
    received: u64,
    frames_sent: u64,
    frames_received: u64,
    /// Reused frame-encode scratch (cleared per send).
    enc_buf: Vec<u8>,
    /// Reused frame-payload read scratch (resized per recv).
    dec_buf: Vec<u8>,
}

impl Framed {
    fn new(stream: TcpStream) -> CommResult<Self> {
        // One small frame per barrier: latency matters, Nagle does not.
        stream.set_nodelay(true).ok();
        let r = BufReader::new(stream.try_clone()?);
        Ok(Framed {
            r,
            w: BufWriter::new(stream),
            liveness: None,
            sent: 0,
            received: 0,
            frames_sent: 0,
            frames_received: 0,
            enc_buf: Vec::new(),
            dec_buf: Vec::new(),
        })
    }

    /// Switch the §14 liveness regime on (`Some`) or off (`None`): the
    /// socket read timeout becomes the heartbeat cadence, so a blocked
    /// receive wakes up to probe instead of waiting forever.
    fn set_liveness(&mut self, ft: Option<FaultTolerance>) -> CommResult<()> {
        self.r
            .get_ref()
            .set_read_timeout(ft.map(|f| f.heartbeat_every))?;
        self.liveness = ft;
        Ok(())
    }

    fn send(&mut self, frame: &Frame) -> CommResult<()> {
        self.enc_buf.clear();
        frame.write_to(&mut self.enc_buf)?;
        self.w.write_all(&self.enc_buf)?;
        self.sent += self.enc_buf.len() as u64;
        self.frames_sent += 1;
        self.w.flush()?;
        Ok(())
    }

    /// Write one pre-encoded frame (fan-out path: encode once, send the
    /// same bytes to every worker).
    fn send_bytes(&mut self, bytes: &[u8]) -> CommResult<()> {
        self.w.write_all(bytes)?;
        self.sent += bytes.len() as u64;
        self.frames_sent += 1;
        self.w.flush()?;
        Ok(())
    }

    /// Receive the next substantive frame. `HeartbeatAck`s — a live but
    /// slow worker answering our probes — are counted and skipped; they
    /// do **not** extend the liveness deadline, which spans the whole
    /// logical receive (otherwise a live-idle worker acking probes could
    /// stall an erroneous wait forever, violating the never-hang
    /// guarantee).
    fn recv(&mut self) -> CommResult<Frame> {
        match self.liveness {
            None => loop {
                let (frame, bytes) = Frame::read_from_reusing(&mut self.r, &mut self.dec_buf)?;
                self.received += bytes as u64;
                self.frames_received += 1;
                self.dec_buf.shrink_to(MAX_RETAINED_PAYLOAD);
                if !matches!(frame, Frame::HeartbeatAck) {
                    return Ok(frame);
                }
            },
            Some(ft) => {
                // dadm-lint: allow(wall-clock) — liveness deadline anchor for this logical receive (§14); drives failure detection, never the algorithm
                let start = Instant::now();
                loop {
                    let frame = self.recv_live(ft, start)?;
                    if !matches!(frame, Frame::HeartbeatAck) {
                        return Ok(frame);
                    }
                }
            }
        }
    }

    /// One deadline-guarded frame receive (scratch-buffer dance around
    /// [`Framed::recv_live_into`], which needs the buffer detached from
    /// `self` to read and probe concurrently).
    fn recv_live(&mut self, ft: FaultTolerance, start: Instant) -> CommResult<Frame> {
        let mut buf = std::mem::take(&mut self.dec_buf);
        let res = self.recv_live_into(ft, start, &mut buf);
        buf.shrink_to(MAX_RETAINED_PAYLOAD);
        self.dec_buf = buf;
        res
    }

    /// Assemble one full frame (header + payload) under the liveness
    /// deadline, then decode it from the completed buffer. Assembling
    /// first is what makes socket-timeout wakeups safe: `read_exact`
    /// leaves unspecified partial state across errors, so the fill loop
    /// below tracks its own progress instead.
    fn recv_live_into(
        &mut self,
        ft: FaultTolerance,
        start: Instant,
        buf: &mut Vec<u8>,
    ) -> CommResult<Frame> {
        buf.resize(FRAME_HEADER_BYTES, 0);
        self.fill_live(ft, start, &mut buf[..])?;
        let len = u32::from_le_bytes([buf[1], buf[2], buf[3], buf[4]]);
        if len > MAX_FRAME_LEN {
            return Err(WireError::FrameTooLarge { len: len as usize }.into());
        }
        buf.resize(FRAME_HEADER_BYTES + len as usize, 0);
        self.fill_live(ft, start, &mut buf[FRAME_HEADER_BYTES..])?;
        let mut r: &[u8] = buf;
        let (frame, bytes) = Frame::read_from(&mut r)?;
        self.received += bytes as u64;
        self.frames_received += 1;
        Ok(frame)
    }

    /// Fill `buf` completely, probing with a `Heartbeat` on every read
    /// timeout and declaring death once `start` ages past the
    /// `worker_timeout` deadline. A dead process surfaces instantly
    /// (EOF / connection reset); a dead route fails the probe write.
    fn fill_live(&mut self, ft: FaultTolerance, start: Instant, buf: &mut [u8]) -> CommResult<()> {
        let mut filled = 0;
        while filled < buf.len() {
            match self.r.read(&mut buf[filled..]) {
                Ok(0) => return Err(CommError::Disconnect { worker: None }),
                Ok(n) => filled += n,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) =>
                {
                    if start.elapsed() >= ft.worker_timeout {
                        return Err(CommError::Timeout { worker: None });
                    }
                    self.send(&Frame::Heartbeat)?;
                }
                Err(e) => return Err(e.into()),
            }
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------
// Coordinator side
// ---------------------------------------------------------------------

/// A bound-but-not-yet-connected cluster (split from [`TcpCluster`] so
/// callers can learn the ephemeral port before spawning workers).
pub struct TcpClusterBuilder {
    listener: TcpListener,
    ft: FaultTolerance,
}

impl TcpClusterBuilder {
    /// Bind the coordinator listener (e.g. `"127.0.0.1:0"`).
    pub fn bind(addr: &str) -> CommResult<Self> {
        Ok(TcpClusterBuilder {
            listener: TcpListener::bind(addr)?,
            ft: FaultTolerance::default(),
        })
    }

    /// The bound address (resolves `:0` to the actual port).
    pub fn local_addr(&self) -> CommResult<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Set the §14 liveness/resurrection policy (defaults to
    /// [`FaultTolerance::default`]: 30 s deadline, 5 s probes, no
    /// resurrection).
    pub fn fault_tolerance(mut self, ft: FaultTolerance) -> Self {
        self.ft = ft;
        self
    }

    /// Accept and handshake exactly `m` workers (accept order = machine
    /// index). A worker speaking the wrong magic/version receives an
    /// `Error` frame and the accept returns a typed
    /// [`CommError::VersionSkew`] / [`CommError::Decode`] — never
    /// panics. The listener is retained for §14 resurrection.
    pub fn accept(self, m: usize) -> CommResult<TcpCluster> {
        if m < 1 {
            return Err(proto("need at least one worker".into()));
        }
        let mut conns = Vec::with_capacity(m);
        for worker_id in 0..m {
            let (stream, _peer) = self.listener.accept()?;
            let mut conn = Framed::new(stream)?;
            conn.set_liveness(Some(self.ft))?;
            let hello = conn.recv()?;
            if let Err(e) = hello.expect_hello() {
                let _ = conn.send(&Frame::Error {
                    message: format!("{e}"),
                });
                return Err(e.into());
            }
            conn.send(&Frame::Welcome {
                version: WIRE_VERSION,
                worker_id: worker_id as u32,
                machines: m as u32,
            })?;
            conns.push(conn);
        }
        Ok(TcpCluster {
            listener: self.listener,
            ft: self.ft,
            conns,
            shut_down: false,
            frame_buf: Vec::new(),
            delta_reply_bytes: 0,
            specs: Vec::new(),
            shadow_v: Vec::new(),
            replay: Vec::new(),
            inflight: VecDeque::new(),
            rejoins_used: 0,
            rejoins_pending: 0,
        })
    }
}

/// One worker's reply to a fused `LocalStep` round: the `Δv_ℓ` message
/// plus whatever gap telemetry the [`StepFlags`] asked it to piggyback
/// (DESIGN.md §11).
#[derive(Clone, Debug)]
pub struct StepReply {
    /// The `Δv_ℓ` message (exactly what the reduce consumes).
    pub delta: Delta,
    /// `Σφ_i(x_iᵀw)` at the entering (just-synced) iterate, when
    /// requested.
    pub loss_sum: Option<f64>,
    /// Post-step running `Σ−φ*(−α)`, when requested.
    pub conj_sum: Option<f64>,
}

/// The coordinator's view of the worker fleet: one framed connection per
/// machine, in machine order — plus the §14 resurrection state: the
/// retained listener, the per-machine [`ProblemSpec`]s, the replay log
/// of retired state-mutating frames, the in-flight (issued but not yet
/// retired) frames, and a shadow of the workers' ṽ replica used as the
/// bitwise determinism cross-check in the `Rejoin` handshake.
pub struct TcpCluster {
    /// Retained after accept so replacement workers can reconnect.
    listener: TcpListener,
    ft: FaultTolerance,
    conns: Vec<Framed>,
    shut_down: bool,
    /// Reused fan-out encode scratch (one encode, m sends).
    frame_buf: Vec<u8>,
    /// Cumulative bytes of received `DeltaReply` frames.
    delta_reply_bytes: u64,
    /// The specs as assigned, in machine order (resurrection re-ships
    /// the dead machine's).
    specs: Vec<ProblemSpec>,
    /// Shadow of every worker's ṽ replica, advanced at frame-retire
    /// time by re-decoding the retired frame's exact wire bytes — the
    /// same bytes every worker applied, so the shadow matches the
    /// replicas bit for bit (codec images round-trip exactly, §13).
    /// Empty when resurrection is disabled.
    shadow_v: Vec<f64>,
    /// Encoded state-mutating frames every worker has fully processed
    /// (retired), in order — the `Rejoin` replay log.
    replay: Vec<Vec<u8>>,
    /// Encoded fan-out frames issued but not yet retired (≤ 2 deep
    /// under the overlapped engine) — resent verbatim to a resurrected
    /// worker after its replay.
    inflight: VecDeque<Vec<u8>>,
    /// Resurrections performed over the cluster's lifetime.
    rejoins_used: u32,
    /// Resurrections since the last [`TcpCluster::take_rejoins`] — the
    /// engine's `RoundOutcome::retried` telemetry feed.
    rejoins_pending: usize,
}

impl std::fmt::Debug for TcpCluster {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TcpCluster")
            .field("workers", &self.conns.len())
            .field("rejoins_used", &self.rejoins_used)
            .field("stats", &self.stats())
            .finish()
    }
}

impl TcpCluster {
    /// Number of connected workers `m`.
    pub fn workers(&self) -> usize {
        self.conns.len()
    }

    /// Cumulative transport counters (summed over connections; a
    /// resurrected connection inherits its predecessor's counters, so
    /// the totals are monotone across deaths).
    pub fn stats(&self) -> WireStats {
        let mut s = WireStats::default();
        for c in &self.conns {
            s.bytes_sent += c.sent;
            s.bytes_received += c.received;
            s.frames_sent += c.frames_sent;
            s.frames_received += c.frames_received;
        }
        s.delta_reply_bytes = self.delta_reply_bytes;
        s
    }

    /// The active §14 policy.
    pub fn fault_tolerance(&self) -> FaultTolerance {
        self.ft
    }

    /// Resurrections performed over the cluster's lifetime.
    pub fn rejoins_total(&self) -> u32 {
        self.rejoins_used
    }

    /// Drain the resurrections-since-last-call counter (the engine's
    /// per-round `RoundOutcome::retried` telemetry hook).
    pub fn take_rejoins(&mut self) -> usize {
        std::mem::take(&mut self.rejoins_pending)
    }

    /// Whether replay/shadow state is being tracked (resurrection on).
    fn track(&self) -> bool {
        self.ft.max_rejoins > 0
    }

    fn rejoins_left(&self) -> bool {
        self.rejoins_used < self.ft.max_rejoins
    }

    fn spec_dim(spec: &ProblemSpec) -> usize {
        match &spec.data {
            DataSpec::Synthetic(s) => s.d,
            DataSpec::Shard { dim, .. } => *dim as usize,
        }
    }

    /// Upgrade a connection-death error into the terminal typed fault
    /// the acceptance criteria require when resurrection cannot run;
    /// other errors are merely attributed to the machine.
    fn death_error(&self, l: usize, e: CommError) -> CommError {
        if e.is_connection_death() {
            let why = if self.ft.max_rejoins == 0 {
                "resurrection disabled (--max-rejoins 0)".to_string()
            } else {
                format!("rejoin budget exhausted ({} used)", self.rejoins_used)
            };
            let e = e.for_worker(l as u32);
            fault(l, format!("declared dead ({e}); {why}"))
        } else {
            e.for_worker(l as u32)
        }
    }

    /// Receive worker `l`'s next frame, healing connection death by
    /// resurrection when the budget allows: the `Rejoin` replay rebuilds
    /// the dead machine and the in-flight resend re-issues whatever
    /// frame this receive was waiting on, so the retry loop converges.
    fn recv_or_recover(&mut self, l: usize) -> CommResult<Frame> {
        loop {
            match self.conns[l].recv() {
                Ok(f) => return Ok(f),
                Err(e) if e.is_connection_death() && self.rejoins_left() => self.resurrect(l)?,
                Err(e) => return Err(self.death_error(l, e)),
            }
        }
    }

    fn expect_ack(&mut self, l: usize) -> CommResult<()> {
        match self.recv_or_recover(l)? {
            Frame::Ack => Ok(()),
            Frame::Error { message } => Err(fault(l, message)),
            other => Err(fault(l, format!("expected Ack, got {other:?}"))),
        }
    }

    /// Ship one [`ProblemSpec`] per worker (machine order) and await the
    /// build acknowledgements. The specs are remembered *before* any
    /// send so a worker that dies mid-assignment can be resurrected —
    /// the `Rejoin` handshake rebuilds from the stored spec, and its Ack
    /// doubles as the build acknowledgement (`AssignPartition` is never
    /// part of the in-flight window).
    pub fn assign(&mut self, specs: Vec<ProblemSpec>) -> CommResult<()> {
        if specs.len() != self.conns.len() {
            return Err(proto(format!(
                "got {} specs for {} workers",
                specs.len(),
                self.conns.len()
            )));
        }
        for (l, spec) in specs.iter().enumerate() {
            if spec.worker as usize != l || spec.machines as usize != self.conns.len() {
                return Err(proto(format!(
                    "spec {l} is for worker {}/{} machines",
                    spec.worker, spec.machines
                )));
            }
        }
        self.specs = specs;
        if self.track() {
            self.shadow_v = vec![0.0; self.specs.first().map_or(0, Self::spec_dim)];
            self.replay.clear();
            self.inflight.clear();
        }
        // Fan the specs out first so the workers build concurrently;
        // `covered` marks machines whose build was acknowledged through
        // a mid-assignment resurrection instead of a plain Ack.
        let mut covered = vec![false; self.conns.len()];
        for l in 0..self.conns.len() {
            let frame = Frame::AssignPartition(Box::new(self.specs[l].clone()));
            if let Err(e) = self.conns[l].send(&frame) {
                if e.is_connection_death() && self.rejoins_left() {
                    self.resurrect(l)?;
                    covered[l] = true;
                } else {
                    return Err(self.death_error(l, e));
                }
            }
        }
        for l in 0..self.conns.len() {
            if covered[l] {
                continue;
            }
            match self.conns[l].recv() {
                Ok(Frame::Ack) => {}
                Ok(Frame::Error { message }) => return Err(fault(l, message)),
                Ok(other) => return Err(fault(l, format!("expected Ack, got {other:?}"))),
                Err(e) if e.is_connection_death() && self.rejoins_left() => self.resurrect(l)?,
                Err(e) => return Err(self.death_error(l, e)),
            }
        }
        Ok(())
    }

    fn send_all_bytes(&mut self, bytes: &[u8]) -> CommResult<()> {
        for l in 0..self.conns.len() {
            if let Err(e) = self.conns[l].send_bytes(bytes) {
                if e.is_connection_death() && self.rejoins_left() {
                    // The in-flight window already holds this frame
                    // (pushed before the fan-out), so the resurrection's
                    // resend delivers it — no direct retry needed.
                    self.resurrect(l)?;
                } else {
                    return Err(self.death_error(l, e));
                }
            }
        }
        Ok(())
    }

    /// Encode one frame into the reusable fan-out scratch and ship the
    /// same bytes to every worker. The buffer always returns to the pool
    /// — even when encoding or a send fails — so the fan-out hot path
    /// never falls back to per-call allocation. Under resurrection
    /// tracking the encoded bytes join the in-flight window *before*
    /// the fan-out, so a send-time death can replay them.
    fn send_all_framed(
        &mut self,
        enc: impl FnOnce(&mut Vec<u8>) -> CommResult<usize>,
    ) -> CommResult<()> {
        let mut buf = std::mem::take(&mut self.frame_buf);
        buf.clear();
        let sent = enc(&mut buf).and_then(|_| {
            if self.track() {
                self.inflight.push_back(buf.clone());
            }
            self.send_all_bytes(&buf)
        });
        self.frame_buf = buf;
        sent
    }

    /// Retire the oldest in-flight frame: every worker has fully
    /// processed it (all replies collected), so it moves to the replay
    /// log and its broadcast advances the shadow ṽ — decoded from the
    /// exact wire bytes the workers applied, for bitwise fidelity.
    fn retire_inflight(&mut self) -> CommResult<()> {
        if !self.track() {
            return Ok(());
        }
        let Some(bytes) = self.inflight.pop_front() else {
            return Ok(());
        };
        let mut r: &[u8] = &bytes;
        let (frame, _) = Frame::read_from(&mut r)?;
        match &frame {
            Frame::Broadcast(b) => self.shadow_apply(b),
            Frame::LocalStep { broadcast, .. } | Frame::Eval { broadcast, .. } => {
                self.shadow_apply(broadcast)
            }
            _ => {}
        }
        self.replay.push(bytes);
        Ok(())
    }

    /// Mirror one broadcast onto the shadow ṽ exactly the way
    /// [`apply_broadcast_to`] drives the worker replicas: same f64
    /// operations in the same order, so shadow and replica stay
    /// bit-identical (DESIGN.md §13).
    fn shadow_apply(&mut self, b: &WireBroadcast) {
        if self.shadow_v.is_empty() {
            return;
        }
        match b {
            WireBroadcast::Empty => {}
            WireBroadcast::SparseSet { idx, val } => {
                for (&j, &x) in idx.iter().zip(val) {
                    self.shadow_v[j as usize] = x;
                }
            }
            WireBroadcast::DenseSet(v) => self.shadow_v.copy_from_slice(v),
            WireBroadcast::Add { delta, .. } => match delta {
                Delta::Sparse(s) => {
                    for (&j, &dv) in s.idx.iter().zip(&s.val) {
                        self.shadow_v[j as usize] += dv;
                    }
                }
                Delta::Dense(v) => {
                    for (sv, dv) in self.shadow_v.iter_mut().zip(v) {
                        *sv += dv;
                    }
                }
            },
        }
    }

    /// Swap every worker's regularizer (Acc-DADM stage transition /
    /// initial resync).
    pub fn set_reg(&mut self, reg: &WireReg) -> CommResult<()> {
        self.send_all_framed(|buf| Frame::SetReg(reg.clone()).write_to(buf))?;
        for l in 0..self.conns.len() {
            self.expect_ack(l)?;
        }
        self.retire_inflight()
    }

    /// Apply a value-setting ṽ update on every worker (resync or
    /// observation flush of a parked `Δṽ`).
    pub fn broadcast(&mut self, b: BroadcastRef<'_>) -> CommResult<()> {
        self.send_all_framed(|buf| write_broadcast(buf, b))?;
        for l in 0..self.conns.len() {
            self.expect_ack(l)?;
        }
        self.retire_inflight()
    }

    /// Ship one fused round leg — parked broadcast + local-step request
    /// (gap-telemetry flags + requested reply codec) — to every worker
    /// *without* waiting for replies. Pairs with
    /// [`TcpCluster::local_step_collect`]; the split is what lets the
    /// overlapped engine keep two rounds' frames outstanding per
    /// connection (DESIGN.md §13): replies come back in FIFO order per
    /// worker, so issue/issue/collect/collect is exactly two sequential
    /// rounds from the worker's point of view.
    pub fn local_step_issue(
        &mut self,
        lambda: f64,
        b: BroadcastRef<'_>,
        flags: StepFlags,
        codec: DeltaCodec,
    ) -> CommResult<()> {
        self.send_all_framed(|buf| write_local_step(buf, lambda, b, flags, codec))
    }

    /// Collect the [`StepReply`]s of the oldest outstanding issued round,
    /// in machine order. Workers compute concurrently (real processes);
    /// the second return is each worker's reported compute seconds, in
    /// machine order — the accounting charges their max as parallel
    /// time, and the straggler telemetry (DESIGN.md §16) records the
    /// min/mean/max spread.
    ///
    /// On a round that resurrects a worker, the per-connection byte span
    /// also covers the rejoin handshake, so `delta_reply_bytes` may be
    /// inflated for that round — transport accounting, never part of the
    /// parity-pinned trace.
    pub fn local_step_collect(
        &mut self,
        flags: StepFlags,
        codec: DeltaCodec,
    ) -> CommResult<(Vec<StepReply>, Vec<f64>)> {
        let mut replies = Vec::with_capacity(self.conns.len());
        let mut leg_secs = Vec::with_capacity(self.conns.len());
        let mut reply_bytes = 0u64;
        for l in 0..self.conns.len() {
            let before = self.conns[l].received;
            match self.recv_or_recover(l)? {
                Frame::DeltaReply {
                    delta,
                    elapsed_secs,
                    loss_sum,
                    conj_sum,
                    codec: reply_codec,
                } => {
                    if loss_sum.is_some() != flags.eval_loss
                        || conj_sum.is_some() != flags.want_conj
                    {
                        return Err(fault(
                            l,
                            "piggybacked telemetry does not match the requested flags".into(),
                        ));
                    }
                    if reply_codec != codec {
                        return Err(fault(
                            l,
                            format!("reply codec {reply_codec:?} != requested {codec:?}"),
                        ));
                    }
                    reply_bytes += self.conns[l].received - before;
                    leg_secs.push(elapsed_secs);
                    replies.push(StepReply {
                        delta,
                        loss_sum,
                        conj_sum,
                    });
                }
                Frame::Error { message } => return Err(fault(l, message)),
                other => return Err(fault(l, format!("expected DeltaReply, got {other:?}"))),
            }
        }
        self.delta_reply_bytes += reply_bytes;
        self.retire_inflight()?;
        Ok((replies, leg_secs))
    }

    /// One fused round leg, synchronously: issue, then collect.
    pub fn local_step(
        &mut self,
        lambda: f64,
        b: BroadcastRef<'_>,
        flags: StepFlags,
        codec: DeltaCodec,
    ) -> CommResult<(Vec<StepReply>, Vec<f64>)> {
        self.local_step_issue(lambda, b, flags, codec)?;
        self.local_step_collect(flags, codec)
    }

    /// Run a scalar instrumentation op on every worker — with the fused
    /// broadcast applied to the replicas first — and combine the replies
    /// by pairwise [`tree_sum`] in machine order, the same combination
    /// the in-process backends use, so the evaluated gap is bit-identical
    /// across backends (workers pre-reduce their own sub-shard sums with
    /// the same tree, DESIGN.md §10).
    pub fn eval_sum(&mut self, op: &EvalOp, b: BroadcastRef<'_>) -> CommResult<f64> {
        self.send_all_framed(|buf| write_eval(buf, op, b))?;
        let mut sums = Vec::with_capacity(self.conns.len());
        for l in 0..self.conns.len() {
            match self.recv_or_recover(l)? {
                Frame::Scalar(x) => sums.push(x),
                Frame::Error { message } => return Err(fault(l, message)),
                other => return Err(fault(l, format!("expected Scalar, got {other:?}"))),
            }
        }
        self.retire_inflight()?;
        Ok(tree_sum(&sums))
    }

    /// The eval-only fused frame (DESIGN.md §11): apply the pending
    /// broadcast and evaluate *both* duality-gap sums in one exchange.
    /// Returns the tree-combined `(Σφ(x_iᵀw), Σ−φ*(−α))`.
    pub fn eval_gap_sums(&mut self, b: BroadcastRef<'_>) -> CommResult<(f64, f64)> {
        self.send_all_framed(|buf| write_eval(buf, &EvalOp::GapSums, b))?;
        let mut losses = Vec::with_capacity(self.conns.len());
        let mut conjs = Vec::with_capacity(self.conns.len());
        for l in 0..self.conns.len() {
            match self.recv_or_recover(l)? {
                Frame::GapReply { loss_sum, conj_sum } => {
                    losses.push(loss_sum);
                    conjs.push(conj_sum);
                }
                Frame::Error { message } => return Err(fault(l, message)),
                other => return Err(fault(l, format!("expected GapReply, got {other:?}"))),
            }
        }
        self.retire_inflight()?;
        Ok((tree_sum(&losses), tree_sum(&conjs)))
    }

    /// OWL-QN smooth-part oracle: per-worker raw `(grad ‖ loss-sum)`
    /// vectors in machine order, plus the slowest worker's compute
    /// seconds.
    pub fn eval_gradients(&mut self, w: &[f64]) -> CommResult<(Vec<Vec<f64>>, f64)> {
        self.send_all_framed(|buf| {
            write_eval(buf, &EvalOp::GradOracle(w.to_vec()), BroadcastRef::Empty)
        })?;
        let mut grads = Vec::with_capacity(self.conns.len());
        let mut parallel_secs = 0.0f64;
        for l in 0..self.conns.len() {
            match self.recv_or_recover(l)? {
                Frame::Vector { v, elapsed_secs } => {
                    parallel_secs = parallel_secs.max(elapsed_secs);
                    grads.push(v);
                }
                Frame::Error { message } => return Err(fault(l, message)),
                other => return Err(fault(l, format!("expected Vector, got {other:?}"))),
            }
        }
        self.retire_inflight()?;
        Ok((grads, parallel_secs))
    }

    /// Replace dead machine `l` with a freshly connected process and
    /// rebuild it bit-identically (DESIGN.md §14): re-admit on the
    /// retained listener (bounded by `worker_timeout`), handshake, ship
    /// the `Rejoin` — original spec + replay log + expected ṽ — await
    /// its Ack (the worker verifies the rebuilt replica bitwise before
    /// acking), then resend the in-flight window in FIFO order so the
    /// interrupted barrier's frames are back on the wire.
    fn resurrect(&mut self, l: usize) -> CommResult<()> {
        self.rejoins_used += 1;
        let spec = self.specs.get(l).cloned().ok_or_else(|| {
            fault(l, "died before AssignPartition; nothing to resurrect".into())
        })?;
        // Poll-accept the replacement: non-blocking with a short sleep,
        // bounded by the same deadline that declared the old one dead.
        self.listener.set_nonblocking(true)?;
        // dadm-lint: allow(wall-clock) — re-admission deadline for the replacement worker (§14); failure detection, never the algorithm
        let start = Instant::now();
        let stream = loop {
            match self.listener.accept() {
                Ok((stream, _peer)) => break stream,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if start.elapsed() >= self.ft.worker_timeout {
                        let _ = self.listener.set_nonblocking(false);
                        return Err(fault(
                            l,
                            format!(
                                "declared dead and no replacement connected within {:?}",
                                self.ft.worker_timeout
                            ),
                        ));
                    }
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => {
                    let _ = self.listener.set_nonblocking(false);
                    return Err(CommError::from(e).for_worker(l as u32));
                }
            }
        };
        self.listener.set_nonblocking(false)?;
        let mut conn = Framed::new(stream)?;
        conn.set_liveness(Some(self.ft))?;
        let hello = conn.recv().map_err(|e| e.for_worker(l as u32))?;
        if let Err(e) = hello.expect_hello() {
            let _ = conn.send(&Frame::Error {
                message: format!("{e}"),
            });
            return Err(e.into());
        }
        conn.send(&Frame::Welcome {
            version: WIRE_VERSION,
            worker_id: l as u32,
            machines: self.conns.len() as u32,
        })?;
        // The replacement inherits the dead connection's counters so the
        // cluster-level transport totals stay monotone.
        conn.sent += self.conns[l].sent;
        conn.received += self.conns[l].received;
        conn.frames_sent += self.conns[l].frames_sent;
        conn.frames_received += self.conns[l].frames_received;
        let mut blob = Vec::new();
        for f in &self.replay {
            blob.extend_from_slice(f);
        }
        conn.send(&Frame::Rejoin {
            worker_id: l as u32,
            spec: Box::new(spec),
            expect_v: self.shadow_v.clone(),
            replay: blob,
        })?;
        match conn.recv() {
            Ok(Frame::Ack) => {}
            Ok(Frame::Error { message }) => return Err(fault(l, message)),
            Ok(other) => return Err(fault(l, format!("expected rejoin Ack, got {other:?}"))),
            Err(e) => return Err(e.for_worker(l as u32)),
        }
        self.conns[l] = conn;
        // Re-prime the pipeline: the not-yet-retired frames go back on
        // the wire oldest-first, so the interrupted barrier (and, under
        // overlap, the round behind it) completes as if uninterrupted.
        for i in 0..self.inflight.len() {
            let bytes = self.inflight[i].clone();
            self.conns[l]
                .send_bytes(&bytes)
                .map_err(|e| e.for_worker(l as u32))?;
        }
        self.rejoins_pending += 1;
        Ok(())
    }

    /// Orderly fleet shutdown (idempotent, best-effort per worker).
    pub fn shutdown(&mut self) {
        if self.shut_down {
            return;
        }
        self.shut_down = true;
        for conn in &mut self.conns {
            let _ = conn.send(&Frame::Shutdown);
        }
    }
}

impl Drop for TcpCluster {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Shared, cloneable handle to a [`TcpCluster`] — the payload of
/// [`super::Cluster::Tcp`]. All coordinator wire ops go through
/// [`TcpHandle::with`], which serializes access (rounds are synchronous;
/// the lock is never contended in a healthy solve).
#[derive(Clone)]
pub struct TcpHandle(Arc<Mutex<TcpCluster>>);

impl TcpHandle {
    /// Wrap a connected cluster.
    pub fn new(cluster: TcpCluster) -> Self {
        TcpHandle(Arc::new(Mutex::new(cluster)))
    }

    /// Run `f` against the cluster under the lock. A poisoned lock (a
    /// panicked round on another thread) is recovered, not propagated:
    /// the panicking round already aborted its solve, and the
    /// `Drop`-driven shutdown path still needs the cluster to send
    /// orderly `Shutdown` frames.
    pub fn with<T>(&self, f: impl FnOnce(&mut TcpCluster) -> T) -> T {
        f(&mut self
            .0
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner))
    }

    /// Number of connected workers `m`.
    pub fn workers(&self) -> usize {
        self.with(|c| c.workers())
    }

    /// Cumulative transport counters.
    pub fn stats(&self) -> WireStats {
        self.with(|c| c.stats())
    }

    /// Whether two handles refer to the same underlying cluster.
    pub fn same_cluster(&self, other: &TcpHandle) -> bool {
        Arc::ptr_eq(&self.0, &other.0)
    }
}

impl std::fmt::Debug for TcpHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self.0.try_lock() {
            Ok(c) => write!(f, "TcpHandle(m={})", c.workers()),
            Err(_) => write!(f, "TcpHandle(<locked>)"),
        }
    }
}

/// Build uniform synthetic-data [`ProblemSpec`]s for every machine —
/// the zero-data-movement assignment path. `local_threads` is the
/// *resolved* intra-machine thread count `T ≥ 1`
/// ([`crate::coordinator::resolve_local_threads`]); it must match the
/// coordinator's `DadmOptions::local_threads` resolution or the
/// machine-local merges will disagree with the cross-machine weights.
///
/// Always ships [`Balance::Rows`]: the worker regenerates the seeded
/// [`Partition::balanced`] locally, which has no nnz-balanced analog —
/// `--balance nnz` synthetic runs ship explicit shards via
/// [`shard_specs`] instead (DESIGN.md §16).
#[allow(clippy::too_many_arguments)]
pub fn synthetic_specs(
    spec: &crate::data::synthetic::SyntheticSpec,
    machines: usize,
    part_seed: u64,
    seed: u64,
    sp: f64,
    loss: WireLoss,
    solver: WireSolver,
    local_threads: usize,
) -> Vec<ProblemSpec> {
    assert!(local_threads >= 1, "ship a resolved local_threads (≥ 1)");
    (0..machines)
        .map(|l| ProblemSpec {
            worker: l as u32,
            machines: machines as u32,
            seed,
            part_seed,
            sp,
            local_threads: local_threads as u32,
            data: DataSpec::Synthetic(spec.clone()),
            loss,
            solver,
            balance: Balance::Rows,
        })
        .collect()
}

/// Build explicit-shard [`ProblemSpec`]s (LIBSVM / externally-loaded
/// data): each worker receives exactly its own rows and sub-partitions
/// them locally into `local_threads` contiguous sub-shards with the
/// `balance` chunking formula — [`split_ranges`] for [`Balance::Rows`],
/// [`split_nnz`] for [`Balance::Nnz`] — exactly the coordinator's
/// `Partition::split` / `Partition::split_nnz` (DESIGN.md §16).
#[allow(clippy::too_many_arguments)]
pub fn shard_specs(
    data: &Dataset,
    part: &Partition,
    seed: u64,
    sp: f64,
    loss: WireLoss,
    solver: WireSolver,
    local_threads: usize,
    balance: Balance,
) -> Vec<ProblemSpec> {
    assert!(local_threads >= 1, "ship a resolved local_threads (≥ 1)");
    let m = part.machines();
    (0..m)
        .map(|l| ProblemSpec {
            worker: l as u32,
            machines: m as u32,
            seed,
            part_seed: 0, // unused: the shard is explicit
            sp,
            local_threads: local_threads as u32,
            data: shard_data_spec(data, part, l),
            loss,
            solver,
            balance,
        })
        .collect()
}

/// Build out-of-core cache [`ProblemSpec`]s (wire v6): each worker
/// mmaps `path` locally and serves its contiguous row range zero-copy
/// out of the mapping — **no training rows cross the wire and none are
/// copied on the worker** (DESIGN.md §15). The partition is the
/// contiguous chunking of the `balance` formula — [`split_ranges`]
/// ([`Partition::contiguous`]) for [`Balance::Rows`], [`split_nnz`]
/// over the cache's own `indptr` ([`Partition::contiguous_nnz`]) for
/// [`Balance::Nnz`] — so a text-parsed run with the same contiguous
/// partition is bit-identical. The cache's content hash rides in every
/// spec: a resurrected worker re-opens with
/// [`crate::data::CsrCache::open_expecting`], so its state is provably
/// a pure function of `(spec, replayed frames)` even though the bytes
/// live on local disk.
///
/// `path` is shipped verbatim — it must resolve to the same compiled
/// cache on every worker host (shared filesystem or a pre-distributed
/// copy; the hash check catches divergent copies).
#[allow(clippy::too_many_arguments)]
pub fn cache_specs(
    cache: &crate::data::CsrCache,
    path: &str,
    machines: usize,
    seed: u64,
    sp: f64,
    loss: WireLoss,
    solver: WireSolver,
    local_threads: usize,
    balance: Balance,
) -> Vec<ProblemSpec> {
    assert!(local_threads >= 1, "ship a resolved local_threads (≥ 1)");
    let n = cache.rows();
    assert!(
        n >= machines * local_threads,
        "cache too small: {n} rows for {machines} machines × {local_threads} threads"
    );
    let ranges = match balance {
        Balance::Rows => split_ranges(n, machines),
        Balance::Nnz => split_nnz(cache.nnz_prefix(), machines),
    };
    ranges
        .into_iter()
        .enumerate()
        .map(|(l, r)| ProblemSpec {
            worker: l as u32,
            machines: machines as u32,
            seed,
            part_seed: 0, // unused: the shard range is explicit
            sp,
            local_threads: local_threads as u32,
            data: DataSpec::Cache {
                path: path.to_string(),
                start: r.start as u64,
                end: r.end as u64,
                n_total: n as u64,
                dim: cache.dim() as u32,
                hash: cache.content_hash(),
            },
            loss,
            solver,
            balance,
        })
        .collect()
}

// ---------------------------------------------------------------------
// Worker side
// ---------------------------------------------------------------------

/// One hosted *logical* machine (sub-shard solver): shard state +
/// private RNG + batch size — the TCP twin of the coordinator's
/// in-process `Machine`. A worker process hosts `local_threads` of
/// these and runs their legs concurrently (DESIGN.md §10).
struct HostedMachine {
    state: WorkerState,
    rng: Rng,
    batch: usize,
}

/// The worker process's event-loop state. Hosted computation reports
/// failures as rendered `String`s — [`serve`] ships them verbatim in a
/// [`Frame::Error`] and exits with a typed [`CommError::WorkerFault`].
struct WorkerHost {
    /// The hosted sub-solvers, in logical order `l·T .. (l+1)·T`
    /// (empty until `AssignPartition`).
    subs: Vec<HostedMachine>,
    /// Global leaf weights `n_k/n` of the hosted sub-shards — exactly
    /// the coordinator's logical weights, so the machine-local merge is
    /// the flat tree's intra-machine subtree.
    weights: Vec<f64>,
    /// Resolved intra-machine thread count `T`.
    threads: usize,
    loss: Option<WireLoss>,
    solver: Option<WireSolver>,
    /// Current regularizer; pushed by `SetReg` before any use (the
    /// coordinator's resync precedes every round).
    reg: Option<WireReg>,
}

impl WorkerHost {
    fn new() -> Self {
        WorkerHost {
            subs: Vec::new(),
            weights: Vec::new(),
            threads: 1,
            loss: None,
            solver: None,
            reg: None,
        }
    }

    fn assigned(&self) -> Result<(), String> {
        wensure!(
            !self.subs.is_empty(),
            "no partition assigned (AssignPartition must precede this frame)"
        );
        Ok(())
    }

    fn dim(&self) -> usize {
        self.subs.first().map_or(0, |s| s.state.dim())
    }

    fn build(&mut self, spec: ProblemSpec) -> Result<(), String> {
        let l = spec.worker as usize;
        let m = spec.machines as usize;
        let t = spec.local_threads as usize;
        let (states, n_total) = match spec.data {
            DataSpec::Synthetic(s) => {
                // Regenerate locally; the training data never crossed the
                // wire. Same generator + same partition seed ⇒ exactly
                // the logical sub-shards the coordinator's in-process
                // twin holds (`Partition::split` of the same balanced
                // partition).
                wensure!(
                    spec.balance == Balance::Rows,
                    "synthetic specs regenerate a seeded balanced partition, \
                     which has no nnz form — nnz-balanced runs ship explicit \
                     shards (DESIGN.md §16)"
                );
                let data = s.generate();
                wensure!(
                    data.n() >= m,
                    "synthetic spec too small: n = {} for m = {m}",
                    data.n()
                );
                let part = Partition::balanced(data.n(), m, spec.part_seed);
                wensure!(
                    part.min_shard() >= t,
                    "local_threads = {t} exceeds the smallest shard ({})",
                    part.min_shard()
                );
                let lpart = part.split(t);
                let states: Vec<WorkerState> = (0..t)
                    .map(|k| WorkerState::from_partition(&data, &lpart, l * t + k))
                    .collect();
                (states, data.n())
            }
            DataSpec::Shard {
                n_total,
                dim,
                global_indices,
                rows,
                y,
            } => {
                wensure!(
                    rows.len() >= t,
                    "local_threads = {t} exceeds the shard size ({})",
                    rows.len()
                );
                // The same contiguous chunking formula as the
                // coordinator's `Partition::split` / `split_nnz`
                // (DESIGN.md §16) — diverging here would fork the
                // logical sub-shards and with them the whole trace.
                let ranges = match spec.balance {
                    Balance::Rows => split_ranges(rows.len(), t),
                    Balance::Nnz => {
                        let mut prefix = Vec::with_capacity(rows.len() + 1);
                        let mut acc = 0u64;
                        prefix.push(acc);
                        for row in &rows {
                            acc += row.len() as u64;
                            prefix.push(acc);
                        }
                        split_nnz(&prefix, t)
                    }
                };
                let mut rows = rows.into_iter();
                let mut y = y.into_iter();
                let mut gi = global_indices.into_iter();
                let states: Vec<WorkerState> = ranges
                    .into_iter()
                    .map(|r| {
                        let len = r.len();
                        WorkerState::from_shard(
                            rows.by_ref().take(len).collect(),
                            y.by_ref().take(len).collect(),
                            gi.by_ref().take(len).map(|g| g as usize).collect(),
                            dim as usize,
                        )
                    })
                    .collect();
                (states, n_total as usize)
            }
            DataSpec::Cache {
                path,
                start,
                end,
                n_total,
                dim,
                hash,
            } => {
                // Out-of-core shard source: mmap the local compiled
                // cache and serve our contiguous row range zero-copy.
                // `open_expecting` pins the cache *identity* — a
                // resurrected worker provably re-maps the same bytes
                // the dead worker trained on (DESIGN.md §15.5).
                let cache = crate::data::CsrCache::open_expecting(
                    std::path::Path::new(&path),
                    hash,
                )
                .map_err(|e| format!("cache shard {path:?}: {e}"))?;
                wensure!(
                    cache.rows() as u64 == n_total,
                    "cache {path:?} has {} rows but the spec says n = {n_total}",
                    cache.rows()
                );
                wensure!(
                    cache.dim() as u64 == u64::from(dim),
                    "cache {path:?} has dimension {} but the spec says d = {dim}",
                    cache.dim()
                );
                let (lo, hi) = (start as usize, end as usize);
                wensure!(
                    hi - lo >= t,
                    "local_threads = {t} exceeds the shard size ({})",
                    hi - lo
                );
                let labels = cache.labels();
                // The same contiguous chunking formula as the
                // coordinator's `Partition::split` / `split_nnz`; the
                // nnz form reads the cache's own `indptr` section, whose
                // arbitrary base offset `split_nnz` accepts verbatim.
                let ranges = match spec.balance {
                    Balance::Rows => split_ranges(hi - lo, t),
                    Balance::Nnz => split_nnz(&cache.nnz_prefix()[lo..=hi], t),
                };
                let states: Vec<WorkerState> = ranges
                    .into_iter()
                    .map(|r| {
                        let (a, b) = (lo + r.start, lo + r.end);
                        let x = cache
                            .matrix_range(a..b)
                            .map_err(|e| format!("cache shard {path:?}: {e}"))?;
                        Ok(WorkerState::from_matrix(
                            x,
                            labels[a..b].to_vec(),
                            (a..b).collect(),
                        ))
                    })
                    .collect::<Result<_, String>>()?;
                (states, n_total as usize)
            }
        };
        // Logical RNG streams l·T .. (l+1)·T, the flat fork discipline.
        let rngs = machine_rngs(spec.seed, l * t, t);
        self.subs = states
            .into_iter()
            .zip(rngs)
            .map(|(state, rng)| HostedMachine {
                batch: batch_size(spec.sp, state.n_l()),
                state,
                rng,
            })
            .collect();
        self.weights = self
            .subs
            .iter()
            .map(|s| s.state.n_l() as f64 / n_total as f64)
            .collect();
        self.threads = t;
        self.loss = Some(spec.loss);
        self.solver = Some(spec.solver);
        Ok(())
    }

    /// Bounds-check a broadcast against the hosted dimension once, so
    /// the per-sub apply inside a parallel section is infallible.
    fn validate_broadcast(&self, b: &WireBroadcast) -> Result<(), String> {
        let d = self.dim();
        match b {
            WireBroadcast::Empty => {}
            WireBroadcast::SparseSet { idx, .. } => {
                if let Some(&j) = idx.last() {
                    wensure!((j as usize) < d, "broadcast index {j} out of bounds (d = {d})");
                }
            }
            WireBroadcast::DenseSet(v) => {
                wensure!(v.len() == d, "broadcast dimension {} != {d}", v.len());
            }
            WireBroadcast::Add { delta, .. } => {
                // The decoder already enforces idx < delta.dim; only the
                // hosted dimension needs checking here.
                wensure!(delta.dim() == d, "broadcast dimension {} != {d}", delta.dim());
            }
        }
        Ok(())
    }

    fn apply_broadcast(&mut self, b: &WireBroadcast) -> Result<(), String> {
        let reg = self.reg.clone().ok_or("no regularizer set")?;
        self.assigned()?;
        self.validate_broadcast(b)?;
        run_subgroup(self.threads > 1, &mut self.subs, |_, sub| {
            apply_broadcast_to(&mut sub.state, b, &reg);
        });
        Ok(())
    }

    /// Verify the rebuilt ṽ replica against the coordinator's shadow,
    /// bit for bit — any mismatch means the resurrection would fork the
    /// trace, which must fail loudly instead of silently diverging.
    fn verify_v_tilde(&self, expect_v: &[f64]) -> Result<(), String> {
        let v = &self
            .subs
            .first()
            .ok_or("rejoin rebuilt no sub-solvers")?
            .state
            .v_tilde;
        wensure!(
            v.len() == expect_v.len(),
            "rebuilt ṽ dimension {} != expected {}",
            v.len(),
            expect_v.len()
        );
        for (k, (a, b)) in v.iter().zip(expect_v).enumerate() {
            wensure!(
                a.to_bits() == b.to_bits(),
                "rebuilt ṽ[{k}] = {a:e} != expected {b:e}: resurrection would fork the trace"
            );
        }
        Ok(())
    }

    /// Handle one frame; `Ok(None)` means orderly shutdown.
    fn handle(&mut self, frame: Frame) -> Result<Option<Frame>, String> {
        Ok(Some(match frame {
            Frame::AssignPartition(spec) => {
                self.build(*spec)?;
                Frame::Ack
            }
            Frame::SetReg(reg) => {
                self.reg = Some(reg);
                Frame::Ack
            }
            Frame::Broadcast(b) => {
                self.apply_broadcast(&b)?;
                Frame::Ack
            }
            Frame::Heartbeat => Frame::HeartbeatAck,
            Frame::Rejoin {
                worker_id,
                spec,
                expect_v,
                replay,
            } => {
                // Become the dead machine, bit-identically (§14): rebuild
                // from the original spec, then re-handle every logged
                // frame in order, discarding the replies — worker state
                // is a pure function of (spec, frame sequence) — and
                // finally verify the rebuilt ṽ against the coordinator's
                // shadow before acking.
                wensure!(
                    worker_id == spec.worker,
                    "rejoin for worker {worker_id} carries a spec for worker {}",
                    spec.worker
                );
                self.build(*spec)?;
                let mut rest: &[u8] = &replay;
                while !rest.is_empty() {
                    let (frame, _) = Frame::read_from(&mut rest)
                        .map_err(|e| format!("replaying logged frame: {e}"))?;
                    wensure!(
                        !matches!(frame, Frame::Rejoin { .. } | Frame::Shutdown),
                        "illegal frame in replay log: {frame:?}"
                    );
                    let _ = self.handle(frame)?;
                }
                if !expect_v.is_empty() {
                    self.verify_v_tilde(&expect_v)?;
                }
                Frame::Ack
            }
            Frame::LocalStep {
                lambda,
                broadcast,
                flags,
                codec,
            } => {
                wensure!(
                    lambda.is_finite() && lambda > 0.0,
                    "λ must be positive and finite, got {lambda}"
                );
                let loss = self.loss.ok_or("no loss assigned")?;
                let solver = self.solver.ok_or("no solver assigned")?;
                let reg = self.reg.clone().ok_or("no regularizer set")?;
                self.assigned()?;
                self.validate_broadcast(&broadcast)?;
                // dadm-lint: allow(wall-clock) — elapsed-seconds telemetry shipped in the reply; never control flow
                let t0 = Instant::now();
                // Fused section, mirroring the in-process round exactly:
                // apply the parked Δṽ, piggyback the requested gap
                // telemetry (loss sum at the just-synced iterate — i.e.
                // *before* the step — and the post-step running conjugate
                // sum), then run the local step — per sub-shard,
                // concurrently when T > 1 (a top-level pool section in
                // this worker process). Shared with Dadm::round_fused's
                // in-process leg (DESIGN.md §9/§10/§11).
                let threads = self.threads;
                let run = run_subgroup(threads > 1, &mut self.subs, |_, sub| {
                    apply_broadcast_to(&mut sub.state, &broadcast, &reg);
                    run_fused_step(
                        &solver,
                        &mut sub.state,
                        &mut sub.rng,
                        sub.batch,
                        &loss,
                        &reg,
                        lambda,
                        flags.eval_loss,
                        flags.want_conj,
                        flags.resum_conj,
                    )
                });
                let mut deltas = Vec::with_capacity(run.results.len());
                let mut losses = Vec::with_capacity(run.results.len());
                let mut conjs = Vec::with_capacity(run.results.len());
                for (delta, loss_sum, conj_sum) in run.results {
                    deltas.push(delta);
                    losses.extend(loss_sum);
                    conjs.extend(conj_sum);
                }
                // T = 1 ships the raw Δv_ℓ (the coordinator leaf-scales,
                // exactly the pre-hierarchy protocol); T > 1 merges
                // machine-locally with the global n_k/n leaf weights and
                // ships one pre-scaled message — the wire-free merge of
                // DESIGN.md §10. The telemetry scalars pre-reduce with
                // the same machine-local pairwise tree as the eval legs.
                // dadm-lint: allow(total-decoding) — T == 1 guarantees exactly one sub-solver delta
                #[allow(clippy::expect_used)]
                let mut delta = if threads == 1 {
                    deltas.into_iter().next().expect("one sub-solver")
                } else {
                    tree_allreduce_delta(deltas, &self.weights).0
                };
                // Quantize once per machine, at the wire boundary (after
                // the wire-free sub-merge): the error feedback lives on
                // the lead sub-solver, exactly where the in-process leg
                // keeps it (DESIGN.md §13). F64 is the identity.
                compress_delta(&mut delta, codec, &mut self.subs[0].state.residual);
                Frame::DeltaReply {
                    delta,
                    elapsed_secs: t0.elapsed().as_secs_f64(),
                    loss_sum: flags.eval_loss.then(|| tree_sum(&losses)),
                    conj_sum: flags.want_conj.then(|| tree_sum(&conjs)),
                    codec,
                }
            }
            Frame::Eval { op, broadcast } => {
                let loss = self.loss.ok_or("no loss assigned")?;
                let reg = self.reg.clone().ok_or("no regularizer set")?;
                self.assigned()?;
                self.validate_broadcast(&broadcast)?;
                let d = self.dim();
                let threads = self.threads;
                match op {
                    EvalOp::LossSumAt(w) => {
                        wensure!(w.len() == d, "eval dimension {} != {d}", w.len());
                        // Per-sub sums combined by the same pairwise
                        // tree the coordinator uses (bit parity with the
                        // in-process hierarchical eval leg).
                        let run = run_subgroup(threads > 1, &mut self.subs, |_, sub| {
                            apply_broadcast_to(&mut sub.state, &broadcast, &reg);
                            sub.state.primal_loss_sum(&loss, &w)
                        });
                        Frame::Scalar(tree_sum(&run.results))
                    }
                    EvalOp::LossSumAtCurrent => {
                        // Evaluate against this worker's own synchronized
                        // replica w_ℓ — zero payload shipped, bit-identical
                        // to LossSumAt of the coordinator's w because the
                        // replicas are value-set (DESIGN.md §7/§11).
                        let run = run_subgroup(threads > 1, &mut self.subs, |_, sub| {
                            apply_broadcast_to(&mut sub.state, &broadcast, &reg);
                            sub.state.primal_loss_sum(&loss, &sub.state.w)
                        });
                        Frame::Scalar(tree_sum(&run.results))
                    }
                    EvalOp::ConjSum => {
                        let run = run_subgroup(threads > 1, &mut self.subs, |_, sub| {
                            apply_broadcast_to(&mut sub.state, &broadcast, &reg);
                            sub.state.conj_running(&loss)
                        });
                        Frame::Scalar(tree_sum(&run.results))
                    }
                    EvalOp::GapSums => {
                        // The eval-only fused frame: apply the pending
                        // Δṽ, then both gap sums in one pass each.
                        let run = run_subgroup(threads > 1, &mut self.subs, |_, sub| {
                            apply_broadcast_to(&mut sub.state, &broadcast, &reg);
                            let loss_sum = sub.state.primal_loss_sum(&loss, &sub.state.w);
                            (loss_sum, sub.state.conj_running(&loss))
                        });
                        let (losses, conjs): (Vec<f64>, Vec<f64>) =
                            run.results.into_iter().unzip();
                        Frame::GapReply {
                            loss_sum: tree_sum(&losses),
                            conj_sum: tree_sum(&conjs),
                        }
                    }
                    EvalOp::GradOracle(w) => {
                        wensure!(w.len() == d, "eval dimension {} != {d}", w.len());
                        // The same fused shard pass + machine-local
                        // unit-weight pre-reduce the in-process OWL-QN
                        // oracle runs (`grad_oracle_sums`).
                        // dadm-lint: allow(wall-clock) — elapsed-seconds telemetry shipped in the reply; never control flow
                        let t0 = Instant::now();
                        let mut run = run_subgroup(threads > 1, &mut self.subs, |_, sub| {
                            apply_broadcast_to(&mut sub.state, &broadcast, &reg);
                            sub.state.grad_oracle_sums(&loss, &w)
                        });
                        // As in the in-process oracle: a single-vector
                        // pre-reduce is a bitwise identity — skip it.
                        // dadm-lint: allow(total-decoding) — guarded by `len() == 1`, pop cannot fail
                        #[allow(clippy::expect_used)]
                        let grad = if run.results.len() == 1 {
                            run.results.pop().expect("one sub-shard")
                        } else {
                            crate::comm::allreduce::tree_allreduce(
                                &run.results,
                                &vec![1.0; run.results.len()],
                            )
                        };
                        Frame::Vector {
                            v: grad,
                            elapsed_secs: t0.elapsed().as_secs_f64(),
                        }
                    }
                }
            }
            Frame::Shutdown => return Ok(None),
            other => wbail!("unexpected frame on worker: {other:?}"),
        }))
    }
}

/// Apply a pre-validated broadcast to one sub-shard state (infallible —
/// bounds already checked by [`WorkerHost::validate_broadcast`]).
fn apply_broadcast_to<R: crate::reg::Regularizer>(
    state: &mut WorkerState,
    b: &WireBroadcast,
    reg: &R,
) {
    match b {
        WireBroadcast::Empty => {}
        WireBroadcast::SparseSet { idx, val } => state.set_v_tilde_sparse_parts(idx, val, reg),
        WireBroadcast::DenseSet(v) => state.set_v_tilde(v, reg),
        // Compressed Δṽ updates apply as increments: every replica runs
        // the same f64 adds in the same order, so all replicas stay
        // bit-identical to the coordinator's `v_image` shadow
        // (DESIGN.md §13).
        WireBroadcast::Add { delta, .. } => match delta {
            Delta::Sparse(s) => state.add_v_tilde_sparse_parts(&s.idx, &s.val, reg),
            Delta::Dense(v) => state.apply_global(v, reg),
        },
    }
}

/// Serve one coordinator connection until `Shutdown` or disconnect —
/// the body of the `dadm worker` subcommand, also hostable on a thread
/// for in-process tests. A replacement process spawned for §14
/// resurrection runs this very loop: the `Rejoin` frame it receives
/// instead of an `AssignPartition` carries everything needed to become
/// the dead machine.
pub fn serve(stream: TcpStream) -> CommResult<()> {
    let mut conn = Framed::new(stream)?;
    conn.send(&Frame::Hello {
        magic: WIRE_MAGIC,
        version: WIRE_VERSION,
    })?;
    // Await the Welcome, acking any liveness probe that races the
    // handshake (the coordinator's read timeouts apply from accept on).
    let worker_id = loop {
        match conn.recv()? {
            Frame::Welcome { version, worker_id, .. } => {
                if version != WIRE_VERSION {
                    return Err(CommError::VersionSkew {
                        theirs: version,
                        ours: WIRE_VERSION,
                    });
                }
                break worker_id;
            }
            Frame::Heartbeat => conn.send(&Frame::HeartbeatAck)?,
            Frame::Error { message } => {
                return Err(proto(format!("coordinator rejected handshake: {message}")))
            }
            other => return Err(proto(format!("expected Welcome, got {other:?}"))),
        }
    };
    let mut host = WorkerHost::new();
    loop {
        let frame = match conn.recv() {
            Ok(f) => f,
            // Coordinator went away without Shutdown (crash, test abort):
            // exit quietly rather than erroring the whole process tree.
            Err(e) if e.is_connection_death() => return Ok(()),
            Err(e) => return Err(e),
        };
        match host.handle(frame) {
            Ok(Some(reply)) => conn.send(&reply)?,
            Ok(None) => return Ok(()),
            Err(message) => {
                let _ = conn.send(&Frame::Error {
                    message: message.clone(),
                });
                return Err(CommError::WorkerFault {
                    id: worker_id,
                    message,
                });
            }
        }
    }
}

/// `dadm worker --connect host:port` entry point.
pub fn run_worker(addr: &str) -> CommResult<()> {
    serve(TcpStream::connect(addr)?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::Cluster;
    use crate::comm::CostModel;
    use crate::coordinator::{Dadm, DadmOptions, Problem};
    use crate::data::synthetic::SyntheticSpec;
    use crate::loss::SmoothHinge;
    use crate::reg::{ElasticNet, Zero};
    use crate::solver::ProxSdca;
    use std::thread::JoinHandle;

    /// Spawn `m` in-process worker threads against a loopback
    /// coordinator — the thread-hosted twin of real `dadm worker`
    /// processes (the child-process variant lives in
    /// `rust/tests/tcp_cluster.rs` and `rust/tests/chaos.rs`).
    fn loopback(m: usize) -> (TcpHandle, Vec<JoinHandle<CommResult<()>>>) {
        loopback_ft(m, FaultTolerance::default())
    }

    fn loopback_ft(m: usize, ft: FaultTolerance) -> (TcpHandle, Vec<JoinHandle<CommResult<()>>>) {
        let builder = TcpClusterBuilder::bind("127.0.0.1:0")
            .unwrap()
            .fault_tolerance(ft);
        let addr = builder.local_addr().unwrap();
        let threads: Vec<_> = (0..m)
            .map(|_| {
                std::thread::spawn(move || -> CommResult<()> {
                    serve(TcpStream::connect(addr)?)
                })
            })
            .collect();
        let cluster = builder.accept(m).unwrap();
        (TcpHandle::new(cluster), threads)
    }

    fn join_workers(handle: TcpHandle, threads: Vec<JoinHandle<CommResult<()>>>) {
        handle.with(|c| c.shutdown());
        drop(handle);
        for t in threads {
            t.join().expect("worker thread panicked").expect("worker errored");
        }
    }

    fn test_spec() -> SyntheticSpec {
        SyntheticSpec {
            name: "tcp-test".into(),
            n: 160,
            d: 24,
            density: 0.4,
            signal_density: 0.5,
            noise: 0.1,
            seed: 0x7C9,
        }
    }

    fn build_dadm_t(
        data: &Dataset,
        part: &Partition,
        cluster: Cluster,
        local_threads: usize,
    ) -> Dadm<SmoothHinge, ElasticNet, Zero, ProxSdca> {
        Problem::new(data, part)
            .loss(SmoothHinge::default())
            .reg(ElasticNet::new(0.1))
            .lambda(1e-2)
            .build_dadm(
                ProxSdca,
                DadmOptions {
                    sp: 0.25,
                    cluster,
                    cost: CostModel::default(),
                    seed: 0xDAD_A,
                    gap_every: 1,
                    sparse_comm: true,
                    local_threads,
                    conj_resum_every: 64,
                    compress: DeltaCodec::F64,
                    overlap: false,
                },
            )
    }

    fn build_dadm(
        data: &Dataset,
        part: &Partition,
        cluster: Cluster,
    ) -> Dadm<SmoothHinge, ElasticNet, Zero, ProxSdca> {
        build_dadm_t(data, part, cluster, 1)
    }

    #[test]
    fn tcp_rounds_match_serial_bit_for_bit() {
        let spec = test_spec();
        let data = spec.generate();
        let part = Partition::balanced(data.n(), 4, 9);
        let (handle, threads) = loopback(4);
        handle
            .with(|c| {
                c.assign(synthetic_specs(
                    &spec,
                    4,
                    9,
                    0xDAD_A,
                    0.25,
                    WireLoss::SmoothHinge(SmoothHinge::default()),
                    WireSolver::ProxSdca,
                    1,
                ))
            })
            .unwrap();

        let mut serial = build_dadm(&data, &part, Cluster::Serial);
        let mut tcp = build_dadm(&data, &part, Cluster::Tcp(handle.clone()));
        serial.resync();
        tcp.resync();
        for round in 0..6 {
            let (_, comm_s) = serial.round();
            let (_, comm_t) = tcp.round();
            assert_eq!(
                comm_s.to_bits(),
                comm_t.to_bits(),
                "modeled comm diverged at round {round}"
            );
            assert_eq!(serial.w(), tcp.w(), "w diverged at round {round}");
            assert_eq!(serial.v(), tcp.v(), "v diverged at round {round}");
            assert_eq!(
                serial.gap().to_bits(),
                tcp.gap().to_bits(),
                "gap diverged at round {round}"
            );
        }
        assert!(tcp.wire_bytes() > 0, "no wire traffic recorded");
        join_workers(handle, threads);
    }

    #[test]
    fn explicit_shard_assignment_matches_serial() {
        // The LIBSVM-style path: workers receive their rows explicitly
        // (DataSpec::Shard) instead of a generator seed — and must still
        // be bit-identical to the in-process machines.
        let spec = test_spec();
        let data = spec.generate();
        let part = Partition::balanced(data.n(), 3, 5);
        let (handle, threads) = loopback(3);
        handle
            .with(|c| {
                c.assign(shard_specs(
                    &data,
                    &part,
                    0xDAD_A,
                    0.25,
                    WireLoss::SmoothHinge(SmoothHinge::default()),
                    WireSolver::ProxSdca,
                    1,
                    Balance::Rows,
                ))
            })
            .unwrap();
        let mut serial = build_dadm(&data, &part, Cluster::Serial);
        let mut tcp = build_dadm(&data, &part, Cluster::Tcp(handle.clone()));
        serial.resync();
        tcp.resync();
        for round in 0..4 {
            serial.round();
            tcp.round();
            assert_eq!(serial.w(), tcp.w(), "shard-path w diverged at round {round}");
        }
        assert_eq!(serial.gap().to_bits(), tcp.gap().to_bits());
        join_workers(handle, threads);
    }

    #[test]
    fn pipelined_issue_collect_matches_serial_pipeline_bit_for_bit() {
        // Double-buffered rounds over TCP (DESIGN.md §13): two LocalStep
        // frames outstanding per connection, replies drained FIFO. The
        // trajectory must be bit-identical to the in-process backend
        // running the same issue/complete schedule.
        let spec = test_spec();
        let data = spec.generate();
        let part = Partition::balanced(data.n(), 4, 9);
        let (handle, threads) = loopback(4);
        handle
            .with(|c| {
                c.assign(synthetic_specs(
                    &spec,
                    4,
                    9,
                    0xDAD_A,
                    0.25,
                    WireLoss::SmoothHinge(SmoothHinge::default()),
                    WireSolver::ProxSdca,
                    1,
                ))
            })
            .unwrap();
        let mut serial = build_dadm(&data, &part, Cluster::Serial);
        let mut tcp = build_dadm(&data, &part, Cluster::Tcp(handle.clone()));
        serial.resync();
        tcp.resync();
        serial.round_issue(false, false);
        tcp.round_issue(false, false);
        for round in 0..5 {
            serial.round_issue(false, false);
            tcp.round_issue(false, false);
            serial.round_complete();
            tcp.round_complete();
            assert_eq!(serial.w(), tcp.w(), "pipelined w diverged at round {round}");
            assert_eq!(serial.v(), tcp.v(), "pipelined v diverged at round {round}");
        }
        serial.round_complete();
        tcp.round_complete();
        assert_eq!(serial.w(), tcp.w());
        assert_eq!(serial.gap().to_bits(), tcp.gap().to_bits());
        assert_eq!(
            serial.barriers(),
            tcp.barriers(),
            "overlap barrier schedule diverged across backends"
        );
        join_workers(handle, threads);
    }

    #[test]
    fn compressed_i16_rounds_match_serial_bit_for_bit() {
        // Worker-side quantization + error feedback must replicate the
        // in-process path exactly: same residual evolution, same wire
        // images, same iterates.
        let spec = test_spec();
        let data = spec.generate();
        let part = Partition::balanced(data.n(), 4, 9);
        let (handle, threads) = loopback(4);
        handle
            .with(|c| {
                c.assign(synthetic_specs(
                    &spec,
                    4,
                    9,
                    0xDAD_A,
                    0.25,
                    WireLoss::SmoothHinge(SmoothHinge::default()),
                    WireSolver::ProxSdca,
                    1,
                ))
            })
            .unwrap();
        let compressed = |cluster| {
            Problem::new(&data, &part)
                .loss(SmoothHinge::default())
                .reg(ElasticNet::new(0.1))
                .lambda(1e-2)
                .build_dadm(
                    ProxSdca,
                    DadmOptions {
                        sp: 0.25,
                        cluster,
                        sparse_comm: true,
                        compress: DeltaCodec::I16,
                        ..Default::default()
                    },
                )
        };
        let mut serial = compressed(Cluster::Serial);
        let mut tcp = compressed(Cluster::Tcp(handle.clone()));
        serial.resync();
        tcp.resync();
        for round in 0..6 {
            serial.round();
            tcp.round();
            assert_eq!(serial.w(), tcp.w(), "compressed w diverged at round {round}");
            assert_eq!(serial.v(), tcp.v(), "compressed v diverged at round {round}");
        }
        assert_eq!(serial.gap().to_bits(), tcp.gap().to_bits());
        join_workers(handle, threads);
    }

    #[test]
    fn compressed_i16_cuts_delta_reply_bytes_to_a_third() {
        // The PR's wire-cost gate: on an m=8 loopback workload whose
        // per-round support densifies under both codecs, the i16
        // DeltaReply payloads must come in at ≤ 0.3× the exact-f64 run's
        // (dense entries shrink 8 B → 2 B), with the final gap within
        // 10× of exact at equal round budget.
        let spec = SyntheticSpec {
            name: "i16-gate".into(),
            n: 320,
            d: 200,
            density: 0.15,
            signal_density: 0.5,
            noise: 0.1,
            seed: 0x16,
        };
        let data = spec.generate();
        let part = Partition::balanced(data.n(), 8, 11);
        let run = |codec: DeltaCodec| {
            let (handle, threads) = loopback(8);
            handle
                .with(|c| {
                    c.assign(synthetic_specs(
                        &spec,
                        8,
                        11,
                        0xDAD_A,
                        0.5,
                        WireLoss::SmoothHinge(SmoothHinge::default()),
                        WireSolver::ProxSdca,
                        1,
                    ))
                })
                .unwrap();
            let mut dadm = Problem::new(&data, &part)
                .loss(SmoothHinge::default())
                .reg(ElasticNet::new(0.1))
                .lambda(1e-2)
                .build_dadm(
                    ProxSdca,
                    DadmOptions {
                        sp: 0.5,
                        cluster: Cluster::Tcp(handle.clone()),
                        sparse_comm: true,
                        compress: codec,
                        ..Default::default()
                    },
                );
            dadm.resync();
            for _ in 0..8 {
                dadm.round();
            }
            let bytes = dadm.delta_reply_bytes();
            let gap = dadm.gap();
            join_workers(handle, threads);
            (bytes, gap)
        };
        let (bytes_f64, gap_f64) = run(DeltaCodec::F64);
        let (bytes_i16, gap_i16) = run(DeltaCodec::I16);
        assert!(bytes_f64 > 0 && bytes_i16 > 0);
        let ratio = bytes_i16 as f64 / bytes_f64 as f64;
        assert!(
            ratio <= 0.3,
            "i16 DeltaReply bytes {bytes_i16} vs f64 {bytes_f64}: ratio {ratio:.3} > 0.3"
        );
        assert!(
            gap_i16 <= gap_f64 * 10.0,
            "i16 gap {gap_i16:e} drifted past 10× the exact gap {gap_f64:e}"
        );
    }

    #[test]
    fn eval_ops_match_local_computation() {
        let spec = test_spec();
        let data = spec.generate();
        let part = Partition::balanced(data.n(), 2, 9);
        let (handle, threads) = loopback(2);
        handle
            .with(|c| {
                c.assign(synthetic_specs(
                    &spec,
                    2,
                    9,
                    1,
                    1.0,
                    WireLoss::SmoothHinge(SmoothHinge::default()),
                    WireSolver::ProxSdca,
                    1,
                ))
            })
            .unwrap();
        let reg = WireReg::ElasticNet(ElasticNet::new(0.0));
        handle.with(|c| c.set_reg(&reg)).unwrap();
        let w = vec![0.05; data.dim()];
        let got = handle
            .with(|c| c.eval_sum(&EvalOp::LossSumAt(w.clone()), BroadcastRef::Empty))
            .unwrap();
        let loss = SmoothHinge::default();
        let want: f64 = (0..data.n())
            .map(|i| crate::loss::Loss::phi(&loss, data.x.row(i).dot(&w), data.y[i]))
            .sum();
        assert!((got - want).abs() < 1e-12, "{got} vs {want}");
        // All-zero duals: conjugate sum must be exactly the φ*(0) sum.
        let conj = handle
            .with(|c| c.eval_sum(&EvalOp::ConjSum, BroadcastRef::Empty))
            .unwrap();
        let conj_want: f64 = (0..data.n())
            .map(|i| -crate::loss::Loss::conj_neg(&loss, 0.0, data.y[i]))
            .sum();
        assert!((conj - conj_want).abs() < 1e-12);
        join_workers(handle, threads);
    }

    #[test]
    fn acc_dadm_runs_unchanged_over_tcp() {
        // Acc-DADM exercises the full stage machinery over the wire:
        // per-stage SetReg (shifted elastic net) + dense resync
        // broadcasts + λ̃-carrying local steps. Bit parity with Serial.
        use crate::coordinator::AccDadmOptions;
        let spec = test_spec();
        let data = spec.generate();
        let part = Partition::balanced(data.n(), 2, 9);
        let (handle, threads) = loopback(2);
        handle
            .with(|c| {
                c.assign(synthetic_specs(
                    &spec,
                    2,
                    9,
                    0xACC,
                    0.5,
                    WireLoss::SmoothHinge(SmoothHinge::default()),
                    WireSolver::ProxSdca,
                    1,
                ))
            })
            .unwrap();
        let build = |cluster: Cluster| {
            Problem::new(&data, &part)
                .loss(SmoothHinge::default())
                .lambda(1e-3)
                .l1(1e-5)
                .build_acc_dadm(
                    ProxSdca,
                    AccDadmOptions {
                        dadm: DadmOptions {
                            sp: 0.5,
                            cluster,
                            cost: CostModel::free(),
                            seed: 0xACC,
                            gap_every: 1,
                            sparse_comm: false,
                            local_threads: 1,
                            conj_resum_every: 64,
                            compress: DeltaCodec::F64,
                            overlap: false,
                        },
                        ..Default::default()
                    },
                )
        };
        let mut serial = build(Cluster::Serial);
        let mut tcp = build(Cluster::Tcp(handle.clone()));
        let rs = serial.solve(1e-4, 30);
        let rt = tcp.solve(1e-4, 30);
        assert_eq!(rs.rounds, rt.rounds);
        assert_eq!(rs.w, rt.w, "Acc-DADM iterates diverge over TCP");
        assert_eq!(rs.primal.to_bits(), rt.primal.to_bits());
        assert_eq!(rs.dual.to_bits(), rt.dual.to_bits());
        join_workers(handle, threads);
    }

    #[test]
    fn owlqn_runs_unchanged_over_tcp() {
        // The primal baseline's oracle (GradOracle frames) must reduce
        // to the exact in-process sums.
        use crate::loss::Logistic;
        let spec = test_spec();
        let data = spec.generate();
        let part = Partition::balanced(data.n(), 2, 9);
        let (handle, threads) = loopback(2);
        handle
            .with(|c| {
                c.assign(synthetic_specs(
                    &spec,
                    2,
                    9,
                    1,
                    1.0,
                    WireLoss::Logistic,
                    WireSolver::ProxSdca,
                    1,
                ))
            })
            .unwrap();
        let owlqn = |cluster: Cluster| {
            Problem::new(&data, &part)
                .loss(Logistic)
                .lambda(1e-3)
                .l1(1e-4)
                .solve_owlqn(20, cluster, CostModel::free(), 1)
        };
        let serial = owlqn(Cluster::Serial);
        let tcp = owlqn(Cluster::Tcp(handle.clone()));
        assert_eq!(serial.w, tcp.w, "OWL-QN iterates diverge over TCP");
        assert_eq!(serial.objective.to_bits(), tcp.objective.to_bits());
        assert_eq!(serial.passes, tcp.passes);
        join_workers(handle, threads);
    }

    #[test]
    fn local_threads_match_serial_and_flat_over_tcp() {
        // Hierarchical workers (T = 2 sub-solvers per process, real
        // threads behind the socket) must be bit-identical to the
        // in-process (m = 2, T = 2) Serial solve — and both to the flat
        // m·T = 4 Serial solve over the split partition (DESIGN.md §10).
        let spec = test_spec(); // n = 160: 4 | 160, machine shards split evenly
        let data = spec.generate();
        let part = Partition::balanced(data.n(), 2, 9);
        let (handle, threads) = loopback(2);
        handle
            .with(|c| {
                c.assign(synthetic_specs(
                    &spec,
                    2,
                    9,
                    0xDAD_A,
                    0.25,
                    WireLoss::SmoothHinge(SmoothHinge::default()),
                    WireSolver::ProxSdca,
                    2,
                ))
            })
            .unwrap();
        let mut serial = build_dadm_t(&data, &part, Cluster::Serial, 2);
        let mut tcp = build_dadm_t(&data, &part, Cluster::Tcp(handle.clone()), 2);
        let flat_part = part.split(2);
        let mut flat = build_dadm_t(&data, &flat_part, Cluster::Serial, 1);
        serial.resync();
        tcp.resync();
        flat.resync();
        for round in 0..5 {
            let (_, comm_s) = serial.round();
            let (_, comm_t) = tcp.round();
            flat.round();
            assert_eq!(
                comm_s.to_bits(),
                comm_t.to_bits(),
                "modeled comm diverged at round {round}"
            );
            assert_eq!(serial.w(), tcp.w(), "tcp w diverged at round {round}");
            assert_eq!(serial.v(), tcp.v(), "tcp v diverged at round {round}");
            assert_eq!(serial.w(), flat.w(), "flat w diverged at round {round}");
            assert_eq!(serial.v(), flat.v(), "flat v diverged at round {round}");
            assert_eq!(serial.gap().to_bits(), tcp.gap().to_bits());
            assert_eq!(serial.gap().to_bits(), flat.gap().to_bits());
        }
        // The hierarchy's comm accounting sees 2 wire participants, not 4.
        assert_eq!(serial.machines(), 2);
        assert_eq!(serial.local_threads(), 2);
        assert_eq!(flat.machines(), 4);
        join_workers(handle, threads);
    }

    #[test]
    fn shard_assignment_with_local_threads_matches_serial() {
        // The explicit-rows path sub-splits on the worker with the same
        // split_ranges chunking the coordinator uses.
        let spec = test_spec();
        let data = spec.generate();
        let part = Partition::balanced(data.n(), 2, 5);
        let (handle, threads) = loopback(2);
        handle
            .with(|c| {
                c.assign(shard_specs(
                    &data,
                    &part,
                    0xDAD_A,
                    0.25,
                    WireLoss::SmoothHinge(SmoothHinge::default()),
                    WireSolver::ProxSdca,
                    2,
                    Balance::Rows,
                ))
            })
            .unwrap();
        let mut serial = build_dadm_t(&data, &part, Cluster::Serial, 2);
        let mut tcp = build_dadm_t(&data, &part, Cluster::Tcp(handle.clone()), 2);
        serial.resync();
        tcp.resync();
        for round in 0..4 {
            serial.round();
            tcp.round();
            assert_eq!(serial.w(), tcp.w(), "shard-path w diverged at round {round}");
        }
        assert_eq!(serial.gap().to_bits(), tcp.gap().to_bits());
        join_workers(handle, threads);
    }

    #[test]
    fn version_mismatch_is_err_not_panic() {
        let builder = TcpClusterBuilder::bind("127.0.0.1:0").unwrap();
        let addr = builder.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let stream = TcpStream::connect(addr).unwrap();
            let mut conn = Framed::new(stream).unwrap();
            conn.send(&Frame::Hello {
                magic: WIRE_MAGIC,
                version: WIRE_VERSION + 7,
            })
            .unwrap();
            // The coordinator must answer with an Error frame.
            matches!(conn.recv(), Ok(Frame::Error { .. }))
        });
        let err = builder.accept(1).unwrap_err();
        assert!(
            matches!(err, CommError::VersionSkew { .. }),
            "version skew must surface typed, got {err:?}"
        );
        assert!(t.join().unwrap(), "worker did not receive the Error frame");
    }

    #[test]
    fn malformed_handshake_is_err_not_panic() {
        let builder = TcpClusterBuilder::bind("127.0.0.1:0").unwrap();
        let addr = builder.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let mut stream = TcpStream::connect(addr).unwrap();
            // Garbage bytes instead of a Hello frame.
            stream.write_all(&[0xFF; 32]).unwrap();
        });
        assert!(builder.accept(1).is_err());
        t.join().unwrap();
    }

    #[test]
    fn worker_errors_surface_as_err() {
        // An Eval before any AssignPartition must come back as a typed
        // WorkerFault, not a hang or panic.
        let (handle, threads) = loopback(1);
        let res = handle.with(|c| c.eval_sum(&EvalOp::ConjSum, BroadcastRef::Empty));
        let err = res.unwrap_err();
        assert!(
            matches!(err, CommError::WorkerFault { id: 0, .. }),
            "expected WorkerFault, got {err:?}"
        );
        let msg = format!("{err}");
        assert!(msg.contains("no"), "unexpected error: {msg}");
        // The worker exits (with an error) after reporting.
        drop(handle);
        for t in threads {
            assert!(t.join().unwrap().is_err());
        }
    }

    #[test]
    fn silent_worker_times_out_with_typed_error() {
        // A wedged (alive but silent) worker must surface as a typed
        // WorkerFault within the liveness deadline — never a hang
        // (acceptance criterion for resurrection-disabled clusters).
        let ft = FaultTolerance {
            worker_timeout: Duration::from_millis(400),
            heartbeat_every: Duration::from_millis(80),
            max_rejoins: 0,
        };
        let builder = TcpClusterBuilder::bind("127.0.0.1:0")
            .unwrap()
            .fault_tolerance(ft);
        let addr = builder.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let mut conn = Framed::new(TcpStream::connect(addr).unwrap()).unwrap();
            conn.send(&Frame::Hello {
                magic: WIRE_MAGIC,
                version: WIRE_VERSION,
            })
            .unwrap();
            loop {
                match conn.recv().unwrap() {
                    Frame::Welcome { .. } => break,
                    Frame::Heartbeat => conn.send(&Frame::HeartbeatAck).unwrap(),
                    other => panic!("expected Welcome, got {other:?}"),
                }
            }
            // Wedge: keep the socket open but never answer anything.
            std::thread::sleep(Duration::from_millis(1200));
        });
        let mut cluster = builder.accept(1).unwrap();
        let t0 = Instant::now();
        let err = cluster
            .local_step(1e-2, BroadcastRef::Empty, StepFlags::default(), DeltaCodec::F64)
            .unwrap_err();
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "death detection took {:?}",
            t0.elapsed()
        );
        assert!(
            matches!(err, CommError::WorkerFault { id: 0, .. }),
            "expected WorkerFault, got {err:?}"
        );
        let msg = format!("{err}");
        assert!(msg.contains("declared dead"), "{msg}");
        assert!(msg.contains("resurrection disabled"), "{msg}");
        t.join().unwrap();
    }

    #[test]
    fn dead_worker_without_resurrection_is_worker_fault() {
        // A worker process that dies mid-solve surfaces as a typed
        // fault (instant EOF, well before the deadline) when
        // resurrection is off — never a hang, never a panic.
        let ft = FaultTolerance {
            worker_timeout: Duration::from_millis(500),
            heartbeat_every: Duration::from_millis(50),
            max_rejoins: 0,
        };
        let spec = test_spec();
        let builder = TcpClusterBuilder::bind("127.0.0.1:0")
            .unwrap()
            .fault_tolerance(ft);
        let addr = builder.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let mut conn = Framed::new(TcpStream::connect(addr).unwrap()).unwrap();
            conn.send(&Frame::Hello {
                magic: WIRE_MAGIC,
                version: WIRE_VERSION,
            })
            .unwrap();
            loop {
                match conn.recv().unwrap() {
                    Frame::Welcome { .. } => break,
                    Frame::Heartbeat => conn.send(&Frame::HeartbeatAck).unwrap(),
                    other => panic!("expected Welcome, got {other:?}"),
                }
            }
            // Ack the build, then die abruptly (socket drop on return).
            match conn.recv().unwrap() {
                Frame::AssignPartition(_) => conn.send(&Frame::Ack).unwrap(),
                other => panic!("expected AssignPartition, got {other:?}"),
            }
        });
        let mut cluster = builder.accept(1).unwrap();
        cluster
            .assign(synthetic_specs(
                &spec,
                1,
                9,
                1,
                0.25,
                WireLoss::SmoothHinge(SmoothHinge::default()),
                WireSolver::ProxSdca,
                1,
            ))
            .unwrap();
        t.join().unwrap();
        let err = cluster
            .local_step(1e-2, BroadcastRef::Empty, StepFlags::default(), DeltaCodec::F64)
            .unwrap_err();
        assert!(
            matches!(err, CommError::WorkerFault { id: 0, .. }),
            "expected WorkerFault, got {err:?}"
        );
        assert!(format!("{err}").contains("declared dead"), "{err}");
    }

    /// A serve-twin that dies abruptly after replying to its
    /// `die_after`-th LocalStep, then reconnects as the §14 replacement
    /// (the listener backlog parks the connection until the coordinator's
    /// resurrection accepts it) and runs the real [`serve`] loop — which
    /// receives the `Rejoin`, replays, verifies ṽ, and resumes.
    fn mortal_serve(addr: SocketAddr, die_after: usize) -> CommResult<()> {
        let mut conn = Framed::new(TcpStream::connect(addr)?)?;
        conn.send(&Frame::Hello {
            magic: WIRE_MAGIC,
            version: WIRE_VERSION,
        })?;
        loop {
            match conn.recv()? {
                Frame::Welcome { .. } => break,
                Frame::Heartbeat => conn.send(&Frame::HeartbeatAck)?,
                other => return Err(proto(format!("expected Welcome, got {other:?}"))),
            }
        }
        let mut host = WorkerHost::new();
        let mut steps = 0usize;
        loop {
            let frame = match conn.recv() {
                Ok(f) => f,
                Err(e) if e.is_connection_death() => return Ok(()),
                Err(e) => return Err(e),
            };
            let is_step = matches!(frame, Frame::LocalStep { .. });
            match host.handle(frame) {
                Ok(Some(reply)) => conn.send(&reply)?,
                Ok(None) => return Ok(()),
                Err(message) => {
                    let _ = conn.send(&Frame::Error {
                        message: message.clone(),
                    });
                    return Err(proto(message));
                }
            }
            if is_step {
                steps += 1;
                if steps == die_after {
                    drop(conn);
                    return serve(TcpStream::connect(addr)?);
                }
            }
        }
    }

    #[test]
    fn killed_worker_resurrects_bit_identically() {
        // The tentpole pin: a worker that dies mid-solve and rejoins via
        // the §14 protocol must leave the trajectory bit-identical to an
        // uninterrupted Serial run — same w, same v, same gap, every
        // round across the kill.
        let spec = test_spec();
        let data = spec.generate();
        let part = Partition::balanced(data.n(), 2, 9);
        let ft = FaultTolerance {
            worker_timeout: Duration::from_secs(10),
            heartbeat_every: Duration::from_secs(1),
            max_rejoins: 2,
        };
        let builder = TcpClusterBuilder::bind("127.0.0.1:0")
            .unwrap()
            .fault_tolerance(ft);
        let addr = builder.local_addr().unwrap();
        let threads: Vec<JoinHandle<CommResult<()>>> = (0..2)
            .map(|l| {
                std::thread::spawn(move || -> CommResult<()> {
                    if l == 1 {
                        mortal_serve(addr, 2)
                    } else {
                        serve(TcpStream::connect(addr)?)
                    }
                })
            })
            .collect();
        let cluster = builder.accept(2).unwrap();
        let handle = TcpHandle::new(cluster);
        handle
            .with(|c| {
                c.assign(synthetic_specs(
                    &spec,
                    2,
                    9,
                    0xDAD_A,
                    0.25,
                    WireLoss::SmoothHinge(SmoothHinge::default()),
                    WireSolver::ProxSdca,
                    1,
                ))
            })
            .unwrap();
        let mut serial = build_dadm(&data, &part, Cluster::Serial);
        let mut tcp = build_dadm(&data, &part, Cluster::Tcp(handle.clone()));
        serial.resync();
        tcp.resync();
        for round in 0..6 {
            serial.round();
            tcp.round();
            assert_eq!(serial.w(), tcp.w(), "w diverged at round {round} across the kill");
            assert_eq!(serial.v(), tcp.v(), "v diverged at round {round} across the kill");
            assert_eq!(
                serial.gap().to_bits(),
                tcp.gap().to_bits(),
                "gap diverged at round {round} across the kill"
            );
        }
        assert_eq!(
            handle.with(|c| c.rejoins_total()),
            1,
            "exactly one resurrection expected"
        );
        join_workers(handle, threads);
    }
}
