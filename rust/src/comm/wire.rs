//! Length-prefixed binary wire protocol for the TCP cluster backend
//! (DESIGN.md §9).
//!
//! Every frame is `[tag: u8][len: u32 LE][payload: len bytes]`, all
//! multi-byte integers and floats little-endian, no external
//! dependencies. Floats travel as raw `f64` bit patterns, so a value
//! that crosses the wire is **bit-identical** on the other side — the
//! property the Tcp-vs-Serial trace-parity tests pin.
//!
//! Frame table (C = coordinator, W = worker):
//!
//! | tag | frame             | direction | payload |
//! |-----|-------------------|-----------|---------|
//! | 0   | `Hello`           | W → C     | magic `b"DADM"`, version |
//! | 1   | `Welcome`         | C → W     | version, worker id, m |
//! | 2   | `AssignPartition` | C → W     | [`ProblemSpec`] |
//! | 3   | `LocalStep`       | C → W     | effective λ + fused [`WireBroadcast`] + [`StepFlags`] (v3) + reply codec byte (v4) |
//! | 4   | `DeltaReply`      | W → C     | [`Delta`] + elapsed seconds + piggybacked gap sums (v3) + codec byte (v4) |
//! | 5   | `Broadcast`       | C → W     | [`WireBroadcast`] (value-setting or additive (v4) ṽ update) |
//! | 6   | `SetReg`          | C → W     | [`WireReg`] (Acc-DADM stage swaps) |
//! | 7   | `Eval`            | C → W     | [`EvalOp`] + fused [`WireBroadcast`] to apply first (v3) |
//! | 8   | `Scalar`          | W → C     | one `f64` |
//! | 9   | `Vector`          | W → C     | `f64` vector + elapsed seconds |
//! | 10  | `Ack`             | W → C     | empty |
//! | 11  | `Shutdown`        | C → W     | empty |
//! | 12  | `Error`           | both      | UTF-8 message |
//! | 13  | `GapReply`        | W → C     | local `Σφ(x_iᵀw)` + running `Σ−φ*(−α)` |
//! | 14  | `Heartbeat`       | C → W     | empty (liveness probe, v5) |
//! | 15  | `HeartbeatAck`    | W → C     | empty (liveness answer, v5) |
//! | 16  | `Rejoin`          | C → W     | worker id + [`ProblemSpec`] + expected ṽ + replay log (v5) |
//!
//! v3 extends three v2 payloads with *trailing* fields (a flags byte on
//! `LocalStep`, flags + optional telemetry scalars on `DeltaReply`, a
//! fused broadcast on `Eval`); the decoder treats an exactly-exhausted
//! v2-shaped payload as "no extension", so v2 frames remain decodable
//! (pinned by `v2_shaped_payloads_still_decode`) even though the
//! handshake itself requires matching versions.
//!
//! v4 adds quantized delta payloads (DESIGN.md §13): [`Delta`] encodings
//! gain f32 and scaled-i16 kinds, `LocalStep`/`DeltaReply` carry a
//! trailing [`DeltaCodec`] byte written only for non-default codecs —
//! exact-f64 frames stay *byte-identical* to their v3 shape — and
//! [`WireBroadcast`] gains an additive kind whose payload reuses the
//! self-describing delta encoding (compressed Δṽ updates).
//!
//! v5 adds the liveness/resurrection frames (DESIGN.md §14): the empty
//! `Heartbeat`/`HeartbeatAck` pair and the `Rejoin` handshake that
//! re-admits a replacement worker mid-solve. No existing payload shape
//! changed, so every v4 payload still decodes byte-for-byte (pinned by
//! `v4_shaped_payloads_still_decode_under_v5`); only the *frame set*
//! grew, which is what the handshake version gate protects.
//!
//! v7 appends a trailing balance byte to [`ProblemSpec`] (DESIGN.md
//! §16): the worker reproduces the coordinator's row- vs nnz-balanced
//! sub-shard cuts from the same chunking formula, so `--balance nnz`
//! keeps the Tcp-vs-Serial trace parity. Every other payload shape is
//! unchanged.
//!
//! Decoding is **total**: malformed input — truncated frames, unknown
//! tags, oversized length prefixes, inconsistent vector lengths,
//! non-increasing sparse indices, trailing bytes — returns `Err` and
//! never panics or makes an attacker-sized allocation ([`MAX_FRAME_LEN`]
//! caps the length prefix, and every element count is validated against
//! the bytes actually present before allocating).

use std::io::{Read, Write};

use crate::comm::error::CommResult;
use crate::comm::sparse::{i16_level, i16_step, max_abs, Delta, DeltaCodec, SparseDelta};
use crate::data::synthetic::SyntheticSpec;
use crate::data::{Balance, Dataset, Partition};
use crate::loss::{Hinge, Logistic, Loss, SmoothHinge, Squared};
use crate::reg::{ElasticNet, Regularizer, ShiftedElasticNet};
use crate::solver::{LocalSolver, ProxSdca, TheoremStep, WorkerState};

/// Module-local result alias: pure codec paths fail with [`WireError`];
/// the socket-touching entry points return [`CommResult`] instead.
type Result<T, E = WireError> = std::result::Result<T, E>;

/// Module-local `bail!`: constructs a [`WireError::Malformed`] and
/// `.into()`s it, so the same macro works in `WireError`- and
/// `CommError`-returning functions alike (no `anyhow` in `comm/`).
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err(WireError::Malformed(format!($($arg)*)).into())
    };
}

/// Module-local `ensure!` over the module-local `bail!`.
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            bail!($($arg)*)
        }
    };
}

/// Protocol magic carried by the worker's `Hello`.
pub const WIRE_MAGIC: [u8; 4] = *b"DADM";
/// Protocol version; bumped on any incompatible frame change.
/// v2: [`ProblemSpec`] carries `local_threads` — remote workers run `T`
/// concurrent sub-shard solvers per machine (DESIGN.md §10).
/// v3: fused gap telemetry (DESIGN.md §11) — `LocalStep` carries
/// [`StepFlags`], `DeltaReply` piggybacks the loss/conjugate sums,
/// `Eval` carries a fused broadcast, and the `GapReply` frame plus the
/// `LossSumAtCurrent`/`GapSums` eval ops evaluate against the worker's
/// own replica so no iterate ships for a gap evaluation.
/// v4: compressed deltas (DESIGN.md §13) — quantized f32/scaled-i16
/// delta kinds (error feedback lives at the sender, not on the wire), a
/// trailing [`DeltaCodec`] byte on `LocalStep`/`DeltaReply`, and an
/// additive broadcast kind for compressed Δṽ updates.
/// v5: fault tolerance (DESIGN.md §14) — the `Heartbeat`/`HeartbeatAck`
/// liveness pair and the `Rejoin` resurrection handshake; all v4 payload
/// shapes are unchanged.
/// v6: out-of-core shard source (DESIGN.md §15) — the trailing
/// [`DataSpec::Cache`] kind (byte 2): workers mmap a locally-accessible
/// compiled cache path + contiguous row range instead of receiving shard
/// rows in `AssignPartition`; the cache's content hash travels in the
/// spec so a resurrected worker provably re-maps the same bytes. Kinds
/// 0/1 and every other payload shape are unchanged.
/// v7: shard balance mode (DESIGN.md §16) — [`ProblemSpec`] carries a
/// trailing [`Balance`] byte so workers derive their intra-machine
/// sub-shard cuts with the same formula (rows vs nnz) as the
/// coordinator; no other payload shape changed.
pub const WIRE_VERSION: u16 = 7;
/// Hard cap on one frame's payload (256 MiB): a corrupt length prefix
/// must never drive a giant allocation.
pub const MAX_FRAME_LEN: u32 = 256 << 20;
/// Fixed per-frame overhead: 1 tag byte + 4 length bytes.
pub const FRAME_HEADER_BYTES: usize = 5;

// ---------------------------------------------------------------------
// Byte-level encoder / decoder
// ---------------------------------------------------------------------

/// Every way the wire codec itself can fail — encode-side caps the
/// caller exceeded, decode-side malformed input, and the handshake
/// version gate. Socket-level failures (EOF, resets, deadlines) are NOT
/// wire errors; they classify into [`crate::comm::CommError`] variants
/// at the transport layer. All variants surface as typed `Err`s, never
/// panics.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WireError {
    /// A collection's element count exceeded the `u32` count field.
    CollectionTooLarge {
        /// The offending element count.
        len: usize,
    },
    /// A frame payload exceeded [`MAX_FRAME_LEN`].
    FrameTooLarge {
        /// The offending payload size in bytes.
        len: usize,
    },
    /// Handshake version disagreement (promoted to
    /// [`crate::comm::CommError::VersionSkew`] at the transport layer).
    VersionSkew {
        /// Version the peer announced.
        got: u16,
        /// Version this side speaks.
        want: u16,
    },
    /// Malformed input: truncated payloads, unknown tags/kinds,
    /// inconsistent lengths, trailing bytes — the total-decoding reject
    /// path, carrying its diagnostic rendered at the reject site.
    Malformed(String),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::CollectionTooLarge { len } => {
                write!(f, "collection too large for wire: {len} elements exceed u32")
            }
            WireError::FrameTooLarge { len } => {
                write!(
                    f,
                    "frame payload too large: {len} bytes exceed cap {MAX_FRAME_LEN}"
                )
            }
            WireError::VersionSkew { got, want } => write!(
                f,
                "protocol version mismatch: peer speaks v{got}, this side v{want}"
            ),
            WireError::Malformed(msg) => write!(f, "{msg}"),
        }
    }
}

impl std::error::Error for WireError {}

/// Append-only little-endian payload builder. Oversized element counts
/// are *recorded* rather than panicking; [`Enc::finish`] converts the
/// record into a [`WireError`] before any byte reaches a socket.
#[derive(Default)]
struct Enc {
    buf: Vec<u8>,
    /// First oversized collection length seen, if any (sticky).
    oversize: Option<usize>,
}

impl Enc {
    fn u8(&mut self, x: u8) {
        self.buf.push(x);
    }

    fn u16(&mut self, x: u16) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }

    fn u32(&mut self, x: u32) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }

    fn u64(&mut self, x: u64) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }

    fn f64(&mut self, x: f64) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }

    /// Element count prefix (u32 — no in-protocol collection exceeds it,
    /// and [`MAX_FRAME_LEN`] bounds it anyway). A count that does not
    /// fit is latched into `oversize` and reported by [`Enc::finish`] —
    /// keeping this method infallible keeps every `put_*` encoder free
    /// of `Result` plumbing without hiding the failure.
    fn count(&mut self, n: usize) {
        match u32::try_from(n) {
            Ok(x) => self.u32(x),
            Err(_) => {
                self.oversize.get_or_insert(n);
                self.u32(u32::MAX);
            }
        }
    }

    fn f64s(&mut self, xs: &[f64]) {
        self.count(xs.len());
        self.buf.reserve(xs.len() * 8);
        for &x in xs {
            self.f64(x);
        }
    }

    fn u32s(&mut self, xs: &[u32]) {
        self.count(xs.len());
        self.buf.reserve(xs.len() * 4);
        for &x in xs {
            self.u32(x);
        }
    }

    /// f32-narrowing vector encode (the f32 codec's value array). The
    /// values are codec *images* — f64s exactly representable in f32 —
    /// so the narrowing cast is lossless.
    fn f32s_narrow(&mut self, xs: &[f64]) {
        self.count(xs.len());
        self.buf.reserve(xs.len() * 4);
        for &x in xs {
            self.buf.extend_from_slice(&(x as f32).to_le_bytes());
        }
    }

    /// Scaled-i16 vector encode (the i16 codec's level array). The
    /// values are codec images `level · step`, so [`i16_level`] recovers
    /// each level exactly.
    fn i16s_quant(&mut self, xs: &[f64], step: f64) {
        self.count(xs.len());
        self.buf.reserve(xs.len() * 2);
        for &x in xs {
            self.buf.extend_from_slice(&i16_level(x, step).to_le_bytes());
        }
    }

    fn str(&mut self, s: &str) {
        self.count(s.len());
        self.buf.extend_from_slice(s.as_bytes());
    }

    /// Opaque byte blob with a count prefix (the `Rejoin` replay log —
    /// already-framed bytes carried verbatim).
    fn bytes(&mut self, b: &[u8]) {
        self.count(b.len());
        self.buf.extend_from_slice(b);
    }

    /// The finished payload — or the latched [`WireError`] if any
    /// collection was too large for its count field.
    fn finish(self) -> Result<Vec<u8>, WireError> {
        match self.oversize {
            Some(len) => Err(WireError::CollectionTooLarge { len }),
            None => Ok(self.buf),
        }
    }
}

/// Copy a length-`N` slice into an array without indexing or `unwrap`:
/// `zip` truncates, so this is total even on a caller bug (which
/// `debug_assert!` would catch in test builds). Every fixed-width read
/// in [`Dec`] funnels through here — the decode layer is literally
/// panic-free, not just panic-free-by-argument.
fn le_array<const N: usize>(chunk: &[u8]) -> [u8; N] {
    debug_assert_eq!(chunk.len(), N);
    let mut out = [0u8; N];
    for (o, &b) in out.iter_mut().zip(chunk) {
        *o = b;
    }
    out
}

/// Consuming little-endian payload reader; every accessor validates the
/// remaining length before touching the buffer.
struct Dec<'a> {
    buf: &'a [u8],
}

impl<'a> Dec<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Dec { buf }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(self.buf.len() >= n, "truncated payload: need {n} more bytes");
        let (head, tail) = self.buf.split_at(n);
        self.buf = tail;
        Ok(head)
    }

    /// Take exactly `N` bytes as a fixed-size array — the single
    /// infallible-conversion point every fixed-width accessor uses.
    fn le_bytes<const N: usize>(&mut self) -> Result<[u8; N]> {
        Ok(le_array(self.take(N)?))
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(u8::from_le_bytes(self.le_bytes()?))
    }

    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.le_bytes()?))
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.le_bytes()?))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.le_bytes()?))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.le_bytes()?))
    }

    /// Element count whose `n · elem_bytes` must fit in the remaining
    /// payload — rejects inflated counts *before* any allocation.
    fn count(&mut self, elem_bytes: usize) -> Result<usize> {
        let n = self.u32()? as usize;
        ensure!(
            n.saturating_mul(elem_bytes) <= self.buf.len(),
            "element count {n} exceeds remaining payload ({} bytes)",
            self.buf.len()
        );
        Ok(n)
    }

    /// Bulk vector decode: one length check + one contiguous take, then
    /// a chunked conversion — the per-round hot path for dense
    /// broadcasts and eval vectors, so no per-element `Result` plumbing.
    fn f64s(&mut self) -> Result<Vec<f64>> {
        let n = self.count(8)?;
        let bytes = self.take(n * 8)?;
        Ok(bytes
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(le_array(c)))
            .collect())
    }

    fn u32s(&mut self) -> Result<Vec<u32>> {
        let n = self.count(4)?;
        let bytes = self.take(n * 4)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(le_array(c)))
            .collect())
    }

    /// f32-widening vector decode (the f32 codec's value array).
    fn f32s_widen(&mut self) -> Result<Vec<f64>> {
        let n = self.count(4)?;
        let bytes = self.take(n * 4)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(le_array(c)) as f64)
            .collect())
    }

    /// Scaled-i16 vector decode: reconstructs the sender's codec images
    /// `level · step` (exact — the step is a power of two).
    fn i16s_dequant(&mut self, step: f64) -> Result<Vec<f64>> {
        let n = self.count(2)?;
        let bytes = self.take(n * 2)?;
        Ok(bytes
            .chunks_exact(2)
            .map(|c| i16::from_le_bytes(le_array(c)) as f64 * step)
            .collect())
    }

    fn str(&mut self) -> Result<String> {
        let n = self.count(1)?;
        String::from_utf8(self.take(n)?.to_vec())
            .map_err(|_| WireError::Malformed("non-UTF-8 string on wire".into()))
    }

    /// Count-prefixed opaque byte blob (the `Rejoin` replay log).
    fn bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.count(1)?;
        Ok(self.take(n)?.to_vec())
    }

    /// Reject trailing garbage after a fully-decoded payload.
    fn finish(self) -> Result<()> {
        ensure!(
            self.buf.is_empty(),
            "{} trailing bytes after frame payload",
            self.buf.len()
        );
        Ok(())
    }
}

// ---------------------------------------------------------------------
// Wire-serializable problem pieces
// ---------------------------------------------------------------------

/// Loss functions as they travel in an [`ProblemSpec`] — the concrete
/// loss zoo behind an enum so the worker process can host the same
/// generic solvers the coordinator runs.
#[derive(Clone, Copy, Debug)]
pub enum WireLoss {
    /// Smooth hinge (carries its γ — Nesterov-smoothed hinge included).
    SmoothHinge(SmoothHinge),
    /// Logistic.
    Logistic,
    /// Non-smooth hinge.
    Hinge,
    /// Squared loss.
    Squared,
}

macro_rules! delegate_loss {
    ($self:ident, $l:ident => $e:expr) => {
        match $self {
            WireLoss::SmoothHinge($l) => $e,
            WireLoss::Logistic => {
                let $l = &Logistic;
                $e
            }
            WireLoss::Hinge => {
                let $l = &Hinge;
                $e
            }
            WireLoss::Squared => {
                let $l = &Squared;
                $e
            }
        }
    };
}

impl Loss for WireLoss {
    #[inline]
    fn phi(&self, u: f64, y: f64) -> f64 {
        delegate_loss!(self, l => l.phi(u, y))
    }

    #[inline]
    fn grad(&self, u: f64, y: f64) -> f64 {
        delegate_loss!(self, l => l.grad(u, y))
    }

    #[inline]
    fn conj_neg(&self, alpha: f64, y: f64) -> f64 {
        delegate_loss!(self, l => l.conj_neg(alpha, y))
    }

    #[inline]
    fn coordinate_delta(&self, alpha: f64, u: f64, q: f64, y: f64) -> f64 {
        delegate_loss!(self, l => l.coordinate_delta(alpha, u, q, y))
    }

    #[inline]
    fn theorem_direction(&self, u: f64, y: f64) -> f64 {
        delegate_loss!(self, l => l.theorem_direction(u, y))
    }

    fn gamma(&self) -> f64 {
        delegate_loss!(self, l => l.gamma())
    }

    fn lipschitz(&self) -> f64 {
        delegate_loss!(self, l => l.lipschitz())
    }

    #[inline]
    fn project_dual(&self, alpha: f64, y: f64) -> f64 {
        delegate_loss!(self, l => l.project_dual(alpha, y))
    }

    fn name(&self) -> &'static str {
        delegate_loss!(self, l => l.name())
    }
}

/// Regularizers as they travel in a `SetReg` frame. The worker applies
/// broadcasts through this, so it must cover every `g` the coordinators
/// use: the elastic net and the Acc-DADM stage shift.
#[derive(Clone, Debug)]
pub enum WireReg {
    /// Elastic net `½‖w‖² + τ‖w‖₁`.
    ElasticNet(ElasticNet),
    /// Linearly-shifted elastic net (Acc-DADM inner stages).
    Shifted(ShiftedElasticNet),
}

macro_rules! delegate_reg {
    ($self:ident, $r:ident => $e:expr) => {
        match $self {
            WireReg::ElasticNet($r) => $e,
            WireReg::Shifted($r) => $e,
        }
    };
}

impl Regularizer for WireReg {
    fn value(&self, w: &[f64]) -> f64 {
        delegate_reg!(self, r => r.value(w))
    }

    fn conj(&self, v: &[f64]) -> f64 {
        delegate_reg!(self, r => r.conj(v))
    }

    #[inline]
    fn grad_conj_at(&self, j: usize, vj: f64) -> f64 {
        delegate_reg!(self, r => r.grad_conj_at(j, vj))
    }

    fn grad_conj_into(&self, v: &[f64], w: &mut [f64]) {
        delegate_reg!(self, r => r.grad_conj_into(v, w))
    }

    fn strong_convexity(&self) -> f64 {
        delegate_reg!(self, r => r.strong_convexity())
    }

    fn name(&self) -> &'static str {
        delegate_reg!(self, r => r.name())
    }

    fn wire_spec(&self) -> Option<WireReg> {
        Some(self.clone())
    }
}

/// Local solvers as they travel in a [`ProblemSpec`].
#[derive(Clone, Copy, Debug)]
pub enum WireSolver {
    /// Sequential aggressive ProxSDCA.
    ProxSdca,
    /// Theorem-6/7 conservative scaled update with data radius `R`.
    Theorem {
        /// Data radius `R ≥ max‖x_i‖²`.
        radius: f64,
    },
}

impl LocalSolver for WireSolver {
    fn local_step<L: Loss, R: Regularizer>(
        &self,
        state: &mut WorkerState,
        batch: &[usize],
        loss: &L,
        reg: &R,
        lambda_n_l: f64,
        rng: &mut crate::utils::Rng,
    ) -> Delta {
        match self {
            WireSolver::ProxSdca => ProxSdca.local_step(state, batch, loss, reg, lambda_n_l, rng),
            WireSolver::Theorem { radius } => TheoremStep { radius: *radius }
                .local_step(state, batch, loss, reg, lambda_n_l, rng),
        }
    }
}

/// Where the worker's shard comes from. `Synthetic` re-generates the
/// dataset from its seed on the worker — **no training data crosses the
/// wire** — while `Shard` ships exactly one machine's rows (LIBSVM /
/// externally-loaded data) and `Cache` (wire v6) ships only a path +
/// row range into a compiled binary cache the worker mmaps locally
/// (DESIGN.md §15): no training data crosses the wire *and* none is
/// copied on the worker.
#[derive(Clone, Debug)]
pub enum DataSpec {
    /// Deterministic synthetic generation + balanced partition; only the
    /// generator parameters travel.
    Synthetic(SyntheticSpec),
    /// Explicit shard payload (this worker's rows only).
    Shard {
        /// Total problem size `n` across all machines.
        n_total: u64,
        /// Feature dimension `d`.
        dim: u32,
        /// Global example indices of the shard rows (debug/trace parity
        /// with [`WorkerState::from_partition`]).
        global_indices: Vec<u64>,
        /// Per-row sparse features `(col, value)`.
        rows: Vec<Vec<(u32, f64)>>,
        /// Shard labels.
        y: Vec<f64>,
    },
    /// Out-of-core shard (wire v6): mmap a compiled cache file that is
    /// accessible on the worker's filesystem and serve rows
    /// `[start, end)` zero-copy. The identity hash keeps the PR-8
    /// resurrection invariant — worker state stays a pure function of
    /// `(spec, frame bytes)` because the spec pins *which bytes* the
    /// cache must contain, and the worker refuses any file whose
    /// recorded identity differs.
    Cache {
        /// Cache file path on the worker's filesystem (shared
        /// filesystem or per-host copy of the same compile output).
        path: String,
        /// First shard row (inclusive).
        start: u64,
        /// One past the last shard row.
        end: u64,
        /// Total problem size `n` across all machines.
        n_total: u64,
        /// Feature dimension `d`.
        dim: u32,
        /// Expected cache identity (`CsrCache::content_hash`).
        hash: u64,
    },
}

/// Everything a worker process needs to reconstruct machine `l`'s state
/// bit-identically to the coordinator's in-process [`WorkerState`]: the
/// data source, the partition/minibatch seeds, and the loss/solver pair.
#[derive(Clone, Debug)]
pub struct ProblemSpec {
    /// Machine index `l` this worker hosts.
    pub worker: u32,
    /// Total machine count `m`.
    pub machines: u32,
    /// Mini-batch RNG seed (`DadmOptions::seed`).
    pub seed: u64,
    /// Balanced-partition seed (`Synthetic` data mode).
    pub part_seed: u64,
    /// Sampling fraction `sp`.
    pub sp: f64,
    /// Intra-machine thread count `T` (≥ 1, already resolved by the
    /// coordinator): the worker hosts logical sub-solvers
    /// `l·T .. (l+1)·T` over contiguous balanced sub-shards and runs
    /// their local steps concurrently (DESIGN.md §10). Wire v2.
    pub local_threads: u32,
    /// Shard source.
    pub data: DataSpec,
    /// Loss `φ`.
    pub loss: WireLoss,
    /// Local solver.
    pub solver: WireSolver,
    /// Chunking formula for the worker's locally derived sub-shards
    /// (rows vs nnz, DESIGN.md §16) — must match the coordinator's or
    /// the `T > 1` logical sub-machines diverge. Wire v7.
    pub balance: Balance,
}

/// Build the explicit-shard [`DataSpec`] for machine `l` (ships only
/// that machine's rows).
pub fn shard_data_spec(data: &Dataset, part: &Partition, l: usize) -> DataSpec {
    let shard = part.shard(l);
    let rows = shard
        .iter()
        .map(|&i| {
            let row = data.x.row(i);
            row.indices
                .iter()
                .copied()
                .zip(row.values.iter().copied())
                .collect()
        })
        .collect();
    DataSpec::Shard {
        n_total: data.n() as u64,
        dim: data.dim() as u32,
        global_indices: shard.iter().map(|&i| i as u64).collect(),
        rows,
        y: shard.iter().map(|&i| data.y[i]).collect(),
    }
}

/// A value-setting ṽ update as broadcast by the global step (the
/// message form of `Δṽ`: changed coordinates carried as new values so
/// worker replicas stay bit-identical to the coordinator).
#[derive(Clone, Debug, Default)]
pub enum WireBroadcast {
    /// Nothing pending.
    #[default]
    Empty,
    /// Sparse value-set at the listed coordinates.
    SparseSet {
        /// Touched coordinates, strictly increasing.
        idx: Vec<u32>,
        /// New `ṽ` values at those coordinates.
        val: Vec<f64>,
    },
    /// Dense replacement of the full `ṽ`.
    DenseSet(Vec<f64>),
    /// Additive update: the carried delta is *added* onto `ṽ` — the
    /// compressed-broadcast form, where quantized Δṽ images plus the
    /// coordinator's error-feedback residual replace the exact value-set
    /// (DESIGN.md §13). v4.
    Add {
        /// The quantized increment; values are codec images.
        delta: Delta,
        /// Codec the payload travels under.
        codec: DeltaCodec,
    },
}

/// Borrowed view of a broadcast for zero-copy encoding (the per-round
/// hot path sends straight from the coordinator's reusable buffers).
#[derive(Clone, Copy, Debug)]
pub enum BroadcastRef<'a> {
    /// Nothing pending.
    Empty,
    /// Sparse value-set.
    SparseSet {
        /// Touched coordinates, strictly increasing.
        idx: &'a [u32],
        /// New values.
        val: &'a [f64],
    },
    /// Dense replacement.
    DenseSet(&'a [f64]),
    /// Additive update (v4, compressed Δṽ).
    Add {
        /// The quantized increment.
        delta: &'a Delta,
        /// Codec the values travel under.
        codec: DeltaCodec,
    },
}

impl WireBroadcast {
    /// Borrow as a [`BroadcastRef`] (named to avoid shadowing
    /// `AsRef::as_ref`).
    pub fn to_ref(&self) -> BroadcastRef<'_> {
        match self {
            WireBroadcast::Empty => BroadcastRef::Empty,
            WireBroadcast::SparseSet { idx, val } => BroadcastRef::SparseSet { idx, val },
            WireBroadcast::DenseSet(v) => BroadcastRef::DenseSet(v),
            WireBroadcast::Add { delta, codec } => BroadcastRef::Add {
                delta,
                codec: *codec,
            },
        }
    }
}

/// Instrumentation requests (duality-gap evaluation, OWL-QN oracle).
#[derive(Clone, Debug)]
pub enum EvalOp {
    /// Local primal sum `Σ φ_i(x_iᵀw)` at the given `w` (Acc-DADM's
    /// original-problem objectives evaluate at reconstructed iterates the
    /// workers do not hold, so the explicit-`w` form must exist — but it
    /// ships `8·d` bytes per machine; current-iterate evals use
    /// [`EvalOp::LossSumAtCurrent`] instead).
    LossSumAt(Vec<f64>),
    /// Local conjugate sum `Σ −φ*(−α_i)` at the current duals (the
    /// worker's running sum — an O(1) read once tracking is armed).
    ConjSum,
    /// OWL-QN smooth-part oracle: raw `(Σ x_i φ'_i ‖ Σ φ_i)` as a
    /// `d + 1` vector.
    GradOracle(Vec<f64>),
    /// Local primal sum `Σ φ_i(x_iᵀw)` at the worker's *own* synchronized
    /// replica `w_ℓ` — bit-identical to [`EvalOp::LossSumAt`] of the
    /// coordinator's `w` (the replicas are value-set, DESIGN.md §7) at
    /// 0 instead of `8·d` payload bytes. v3.
    LossSumAtCurrent,
    /// Both duality-gap sums in one exchange: apply the `Eval` frame's
    /// fused broadcast, then reply [`Frame::GapReply`] with the loss sum
    /// at the replica `w_ℓ` and the running conjugate sum — the
    /// eval-only fused frame the coordinator uses at stop/final-report
    /// time (DESIGN.md §11). v3.
    GapSums,
}

/// Per-round telemetry requests fused into a `LocalStep` frame
/// (DESIGN.md §11). Encoded as one flags byte on the wire; a v2 frame
/// without the byte decodes as all-false.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StepFlags {
    /// Compute `Σφ_i(x_iᵀw)` at the just-synced iterate (immediately
    /// after the fused broadcast apply, before the local step) and
    /// piggyback it in the `DeltaReply` — the one-round-lagged primal
    /// telemetry.
    pub eval_loss: bool,
    /// Piggyback the post-step running `Σ−φ*(−α)` in the `DeltaReply`.
    pub want_conj: bool,
    /// Resum the running conjugate sum exactly after this step (the
    /// drift-bounding cadence, driven by the coordinator's round counter
    /// so every backend and every resumed run resums at the same rounds).
    pub resum_conj: bool,
}

const STEP_FLAG_EVAL_LOSS: u8 = 1 << 0;
const STEP_FLAG_WANT_CONJ: u8 = 1 << 1;
const STEP_FLAG_RESUM_CONJ: u8 = 1 << 2;

impl StepFlags {
    fn to_byte(self) -> u8 {
        (self.eval_loss as u8) * STEP_FLAG_EVAL_LOSS
            | (self.want_conj as u8) * STEP_FLAG_WANT_CONJ
            | (self.resum_conj as u8) * STEP_FLAG_RESUM_CONJ
    }

    fn from_byte(b: u8) -> Result<Self> {
        ensure!(
            b & !(STEP_FLAG_EVAL_LOSS | STEP_FLAG_WANT_CONJ | STEP_FLAG_RESUM_CONJ) == 0,
            "unknown step flag bits {b:#x}"
        );
        Ok(StepFlags {
            eval_loss: b & STEP_FLAG_EVAL_LOSS != 0,
            want_conj: b & STEP_FLAG_WANT_CONJ != 0,
            resum_conj: b & STEP_FLAG_RESUM_CONJ != 0,
        })
    }
}

// ---------------------------------------------------------------------
// Frames
// ---------------------------------------------------------------------

/// One protocol message (see the module-level frame table).
#[derive(Clone, Debug)]
pub enum Frame {
    /// Worker greeting (magic + version).
    Hello {
        /// Must equal [`WIRE_MAGIC`].
        magic: [u8; 4],
        /// Must equal [`WIRE_VERSION`].
        version: u16,
    },
    /// Coordinator acceptance.
    Welcome {
        /// Coordinator protocol version.
        version: u16,
        /// Assigned worker id (accept order).
        worker_id: u32,
        /// Total machine count `m`.
        machines: u32,
    },
    /// Shard assignment.
    AssignPartition(Box<ProblemSpec>),
    /// Fused broadcast-apply + local step request.
    LocalStep {
        /// Effective regularization λ (λ̃ during Acc-DADM stages).
        lambda: f64,
        /// The previous round's parked `Δṽ`.
        broadcast: WireBroadcast,
        /// Fused gap-telemetry requests for this round (v3).
        flags: StepFlags,
        /// Codec the worker must compress this round's `DeltaReply`
        /// under (v4; trailing byte, absent ⇒ [`DeltaCodec::F64`]).
        codec: DeltaCodec,
    },
    /// Local-step result.
    DeltaReply {
        /// The `Δv_ℓ` message (exactly what the reduce consumes).
        delta: Delta,
        /// Worker-side wall-clock seconds for the fused section.
        elapsed_secs: f64,
        /// Piggybacked `Σφ_i(x_iᵀw)` at the entering (just-synced)
        /// iterate, when [`StepFlags::eval_loss`] asked for it (v3).
        loss_sum: Option<f64>,
        /// Piggybacked post-step running `Σ−φ*(−α)`, when
        /// [`StepFlags::want_conj`] asked for it (v3).
        conj_sum: Option<f64>,
        /// Codec the delta payload travels under (v4; trailing byte,
        /// absent ⇒ [`DeltaCodec::F64`], must agree with the delta kind).
        codec: DeltaCodec,
    },
    /// Standalone ṽ update (resync / observation flush).
    Broadcast(WireBroadcast),
    /// Regularizer swap (Acc-DADM stage transitions).
    SetReg(WireReg),
    /// Instrumentation request; the fused broadcast (v3) is applied to
    /// the worker's replica *before* the op runs, so current-iterate
    /// evals see the fully synchronized state without a separate
    /// `Broadcast` round trip.
    Eval {
        /// The instrumentation operation.
        op: EvalOp,
        /// Pending `Δṽ` to apply first ([`WireBroadcast::Empty`] when the
        /// workers are already synced).
        broadcast: WireBroadcast,
    },
    /// Gap-sums reply (answer to [`EvalOp::GapSums`]).
    GapReply {
        /// Local `Σφ_i(x_iᵀw)` at the replica iterate.
        loss_sum: f64,
        /// Local running `Σ−φ*(−α)`.
        conj_sum: f64,
    },
    /// Scalar reply.
    Scalar(f64),
    /// Vector reply (OWL-QN oracle) + worker wall-clock seconds.
    Vector {
        /// Payload vector.
        v: Vec<f64>,
        /// Worker-side wall-clock seconds.
        elapsed_secs: f64,
    },
    /// Success acknowledgement.
    Ack,
    /// Orderly termination request.
    Shutdown,
    /// Failure report (either direction).
    Error {
        /// Human-readable cause.
        message: String,
    },
    /// Liveness probe sent by the coordinator after an idle interval on
    /// a silent connection (v5, DESIGN.md §14). Empty payload.
    Heartbeat,
    /// Liveness answer: the worker replies immediately from its recv
    /// loop, proving the process is alive and draining its socket (v5).
    /// Empty payload.
    HeartbeatAck,
    /// Resurrection handshake (v5, DESIGN.md §14): everything a fresh
    /// replacement process needs to become the dead machine `l`
    /// *bit-identically* — the original [`ProblemSpec`] plus the replay
    /// log of every state-mutating frame the dead worker had fully
    /// processed, in order. The worker rebuilds from the spec, re-handles
    /// the log (its state is a pure function of `(spec, frame sequence)`),
    /// checks its reconstructed ṽ replica against `expect_v` bit for
    /// bit, and replies `Ack`.
    Rejoin {
        /// Machine index `l` being resurrected.
        worker_id: u32,
        /// The dead worker's original assignment.
        spec: Box<ProblemSpec>,
        /// The coordinator's current ṽ replica for machine `l` — the
        /// determinism cross-check the replayed state must reproduce
        /// exactly (covers reg phase, broadcast history, and — under
        /// lossy codecs — the residual-corrected v image).
        expect_v: Vec<f64>,
        /// Concatenated encoded frames (each `[tag][len][payload]`) to
        /// re-handle in order, replies discarded.
        replay: Vec<u8>,
    },
}

const TAG_HELLO: u8 = 0;
const TAG_WELCOME: u8 = 1;
const TAG_ASSIGN: u8 = 2;
const TAG_LOCAL_STEP: u8 = 3;
const TAG_DELTA_REPLY: u8 = 4;
const TAG_BROADCAST: u8 = 5;
const TAG_SET_REG: u8 = 6;
const TAG_EVAL: u8 = 7;
const TAG_SCALAR: u8 = 8;
const TAG_VECTOR: u8 = 9;
const TAG_ACK: u8 = 10;
const TAG_SHUTDOWN: u8 = 11;
const TAG_ERROR: u8 = 12;
const TAG_GAP_REPLY: u8 = 13;
const TAG_HEARTBEAT: u8 = 14;
const TAG_HEARTBEAT_ACK: u8 = 15;
const TAG_REJOIN: u8 = 16;

/// Strict-monotonicity check for sparse index vectors, written with
/// iterator pairing instead of `w[0] < w[1]` windows — the decode layer
/// admits no slice indexing at all (dadm-lint `total-decoding`).
fn strictly_increasing(idx: &[u32]) -> bool {
    idx.iter().zip(idx.iter().skip(1)).all(|(a, b)| a < b)
}

fn put_broadcast(e: &mut Enc, b: BroadcastRef<'_>) {
    match b {
        BroadcastRef::Empty => e.u8(0),
        BroadcastRef::SparseSet { idx, val } => {
            e.u8(1);
            e.u32s(idx);
            e.f64s(val);
        }
        BroadcastRef::DenseSet(v) => {
            e.u8(2);
            e.f64s(v);
        }
        BroadcastRef::Add { delta, codec } => {
            e.u8(3);
            put_delta(e, delta, codec);
        }
    }
}

fn take_broadcast(d: &mut Dec<'_>) -> Result<WireBroadcast> {
    Ok(match d.u8()? {
        0 => WireBroadcast::Empty,
        1 => {
            let idx = d.u32s()?;
            let val = d.f64s()?;
            ensure!(
                idx.len() == val.len(),
                "broadcast idx/val length mismatch: {} vs {}",
                idx.len(),
                val.len()
            );
            ensure!(
                strictly_increasing(&idx),
                "broadcast indices not strictly increasing"
            );
            WireBroadcast::SparseSet { idx, val }
        }
        2 => WireBroadcast::DenseSet(d.f64s()?),
        3 => {
            let (delta, codec) = take_delta(d)?;
            WireBroadcast::Add { delta, codec }
        }
        t => bail!("unknown broadcast kind {t}"),
    })
}

/// One-byte wire form of a [`DeltaCodec`] (the v4 trailing codec byte).
fn codec_byte(codec: DeltaCodec) -> u8 {
    match codec {
        DeltaCodec::F64 => 0,
        DeltaCodec::F32 => 1,
        DeltaCodec::I16 => 2,
    }
}

fn take_codec(b: u8) -> Result<DeltaCodec> {
    Ok(match b {
        0 => DeltaCodec::F64,
        1 => DeltaCodec::F32,
        2 => DeltaCodec::I16,
        t => bail!("unknown delta codec {t}"),
    })
}

/// Append the v4 trailing codec byte — written only for non-default
/// codecs, so exact-f64 frames stay byte-identical to their v3 shape.
fn put_trailing_codec(e: &mut Enc, codec: DeltaCodec) {
    if codec != DeltaCodec::F64 {
        e.u8(codec_byte(codec));
    }
}

fn put_sparse_header(e: &mut Enc, s: &SparseDelta) {
    e.u64(s.dim as u64);
    e.u32s(&s.idx);
}

/// Encode a delta under `codec`. Kind bytes are codec-describing
/// (0/1 dense/sparse f64, 2/3 f32, 4/5 scaled i16), so decoding needs no
/// out-of-band codec. The i16 step is *re-derived* from the image values
/// ([`i16_step`] of their max magnitude): the quantizer's max-magnitude
/// carry always lands on a level in `(16383, 32767]`, so this recovers
/// exactly the step the images were built with — encode → decode →
/// re-encode is byte-stable without shipping quantizer state.
fn put_delta(e: &mut Enc, delta: &Delta, codec: DeltaCodec) {
    match (delta, codec) {
        (Delta::Dense(v), DeltaCodec::F64) => {
            e.u8(0);
            e.f64s(v);
        }
        (Delta::Sparse(s), DeltaCodec::F64) => {
            e.u8(1);
            put_sparse_header(e, s);
            e.f64s(&s.val);
        }
        (Delta::Dense(v), DeltaCodec::F32) => {
            e.u8(2);
            e.f32s_narrow(v);
        }
        (Delta::Sparse(s), DeltaCodec::F32) => {
            e.u8(3);
            put_sparse_header(e, s);
            e.f32s_narrow(&s.val);
        }
        (Delta::Dense(v), DeltaCodec::I16) => {
            e.u8(4);
            let step = i16_step(max_abs(v));
            e.f64(step);
            e.i16s_quant(v, step);
        }
        (Delta::Sparse(s), DeltaCodec::I16) => {
            e.u8(5);
            put_sparse_header(e, s);
            let step = i16_step(max_abs(&s.val));
            e.f64(step);
            e.i16s_quant(&s.val, step);
        }
    }
}

/// Validate a decoded sparse delta's invariants (shared by every sparse
/// kind): aligned lengths, strictly increasing indices, in-bounds.
fn finish_sparse(dim: usize, idx: Vec<u32>, val: Vec<f64>) -> Result<Delta> {
    ensure!(
        idx.len() == val.len(),
        "delta idx/val length mismatch: {} vs {}",
        idx.len(),
        val.len()
    );
    ensure!(
        strictly_increasing(&idx),
        "delta indices not strictly increasing"
    );
    if let Some(&j) = idx.last() {
        ensure!((j as usize) < dim, "delta index {j} out of bounds (d = {dim})");
    }
    Ok(Delta::Sparse(SparseDelta { dim, idx, val }))
}

/// Validated i16-codec step: a corrupt step must not poison the
/// reconstructed images with ∞/NaN.
fn take_step(d: &mut Dec<'_>) -> Result<f64> {
    let step = d.f64()?;
    ensure!(
        step.is_finite() && step > 0.0,
        "i16 codec step must be positive and finite, got {step}"
    );
    Ok(step)
}

fn take_delta(d: &mut Dec<'_>) -> Result<(Delta, DeltaCodec)> {
    Ok(match d.u8()? {
        0 => (Delta::Dense(d.f64s()?), DeltaCodec::F64),
        1 => {
            let dim = d.u64()? as usize;
            let idx = d.u32s()?;
            let val = d.f64s()?;
            (finish_sparse(dim, idx, val)?, DeltaCodec::F64)
        }
        2 => (Delta::Dense(d.f32s_widen()?), DeltaCodec::F32),
        3 => {
            let dim = d.u64()? as usize;
            let idx = d.u32s()?;
            let val = d.f32s_widen()?;
            (finish_sparse(dim, idx, val)?, DeltaCodec::F32)
        }
        4 => {
            let step = take_step(d)?;
            (Delta::Dense(d.i16s_dequant(step)?), DeltaCodec::I16)
        }
        5 => {
            let dim = d.u64()? as usize;
            let idx = d.u32s()?;
            let step = take_step(d)?;
            let val = d.i16s_dequant(step)?;
            (finish_sparse(dim, idx, val)?, DeltaCodec::I16)
        }
        t => bail!("unknown delta kind {t}"),
    })
}

fn put_loss(e: &mut Enc, loss: &WireLoss) {
    match loss {
        WireLoss::SmoothHinge(sh) => {
            e.u8(0);
            e.f64(sh.gamma());
        }
        WireLoss::Logistic => e.u8(1),
        WireLoss::Hinge => e.u8(2),
        WireLoss::Squared => e.u8(3),
    }
}

fn take_loss(d: &mut Dec<'_>) -> Result<WireLoss> {
    Ok(match d.u8()? {
        0 => {
            let gamma = d.f64()?;
            ensure!(
                gamma.is_finite() && gamma > 0.0,
                "smooth hinge γ must be positive and finite, got {gamma}"
            );
            WireLoss::SmoothHinge(SmoothHinge::new(gamma))
        }
        1 => WireLoss::Logistic,
        2 => WireLoss::Hinge,
        3 => WireLoss::Squared,
        t => bail!("unknown loss kind {t}"),
    })
}

fn put_reg(e: &mut Enc, reg: &WireReg) {
    match reg {
        WireReg::ElasticNet(en) => {
            e.u8(0);
            e.f64(en.tau());
        }
        WireReg::Shifted(s) => {
            e.u8(1);
            e.f64(s.base().tau());
            e.f64s(s.shift());
        }
    }
}

fn take_tau(d: &mut Dec<'_>) -> Result<f64> {
    // `ElasticNet::new` asserts; validate first so corrupt input stays Err.
    let tau = d.f64()?;
    ensure!(
        tau.is_finite() && tau >= 0.0,
        "τ must be finite and ≥ 0, got {tau}"
    );
    Ok(tau)
}

fn take_reg(d: &mut Dec<'_>) -> Result<WireReg> {
    Ok(match d.u8()? {
        0 => WireReg::ElasticNet(ElasticNet::new(take_tau(d)?)),
        1 => {
            let tau = take_tau(d)?;
            let shift = d.f64s()?;
            WireReg::Shifted(ShiftedElasticNet::new(ElasticNet::new(tau), shift))
        }
        t => bail!("unknown regularizer kind {t}"),
    })
}

fn put_solver(e: &mut Enc, solver: &WireSolver) {
    match solver {
        WireSolver::ProxSdca => e.u8(0),
        WireSolver::Theorem { radius } => {
            e.u8(1);
            e.f64(*radius);
        }
    }
}

fn take_solver(d: &mut Dec<'_>) -> Result<WireSolver> {
    Ok(match d.u8()? {
        0 => WireSolver::ProxSdca,
        1 => WireSolver::Theorem { radius: d.f64()? },
        t => bail!("unknown solver kind {t}"),
    })
}

fn put_spec(e: &mut Enc, spec: &ProblemSpec) {
    e.u32(spec.worker);
    e.u32(spec.machines);
    e.u64(spec.seed);
    e.u64(spec.part_seed);
    e.f64(spec.sp);
    e.u32(spec.local_threads);
    put_loss(e, &spec.loss);
    put_solver(e, &spec.solver);
    match &spec.data {
        DataSpec::Synthetic(s) => {
            e.u8(0);
            e.str(&s.name);
            e.u64(s.n as u64);
            e.u64(s.d as u64);
            e.f64(s.density);
            e.f64(s.signal_density);
            e.f64(s.noise);
            e.u64(s.seed);
        }
        DataSpec::Shard {
            n_total,
            dim,
            global_indices,
            rows,
            y,
        } => {
            e.u8(1);
            e.u64(*n_total);
            e.u32(*dim);
            e.count(global_indices.len());
            for &g in global_indices {
                e.u64(g);
            }
            e.count(rows.len());
            for row in rows {
                e.count(row.len());
                for &(j, v) in row {
                    e.u32(j);
                    e.f64(v);
                }
            }
            e.f64s(y);
        }
        DataSpec::Cache {
            path,
            start,
            end,
            n_total,
            dim,
            hash,
        } => {
            e.u8(2);
            e.str(path);
            e.u64(*start);
            e.u64(*end);
            e.u64(*n_total);
            e.u32(*dim);
            e.u64(*hash);
        }
    }
    e.u8(match spec.balance {
        Balance::Rows => 0,
        Balance::Nnz => 1,
    });
}

fn take_spec(d: &mut Dec<'_>) -> Result<ProblemSpec> {
    let worker = d.u32()?;
    let machines = d.u32()?;
    ensure!(machines >= 1, "machine count must be ≥ 1");
    ensure!(
        worker < machines,
        "worker index {worker} out of range for m = {machines}"
    );
    let seed = d.u64()?;
    let part_seed = d.u64()?;
    let sp = d.f64()?;
    ensure!(
        sp > 0.0 && sp <= 1.0,
        "sampling fraction must be in (0, 1], got {sp}"
    );
    let local_threads = d.u32()?;
    ensure!(
        local_threads >= 1,
        "local_threads must be ≥ 1 on the wire (the coordinator resolves 0 = auto)"
    );
    let loss = take_loss(d)?;
    let solver = take_solver(d)?;
    let data = match d.u8()? {
        0 => DataSpec::Synthetic(SyntheticSpec {
            name: d.str()?,
            n: d.u64()? as usize,
            d: d.u64()? as usize,
            density: d.f64()?,
            signal_density: d.f64()?,
            noise: d.f64()?,
            seed: d.u64()?,
        }),
        1 => {
            let n_total = d.u64()?;
            let dim = d.u32()?;
            let n_gi = d.count(8)?;
            let global_indices: Vec<u64> = (0..n_gi).map(|_| d.u64()).collect::<Result<_>>()?;
            let n_rows = d.count(4)?;
            let mut rows = Vec::with_capacity(n_rows);
            for _ in 0..n_rows {
                let nnz = d.count(12)?;
                let mut row = Vec::with_capacity(nnz);
                for _ in 0..nnz {
                    let j = d.u32()?;
                    ensure!(j < dim, "shard column {j} out of bounds (d = {dim})");
                    row.push((j, d.f64()?));
                }
                rows.push(row);
            }
            let y = d.f64s()?;
            ensure!(
                rows.len() == y.len() && rows.len() == global_indices.len(),
                "shard rows/labels/indices length mismatch: {}/{}/{}",
                rows.len(),
                y.len(),
                global_indices.len()
            );
            DataSpec::Shard {
                n_total,
                dim,
                global_indices,
                rows,
                y,
            }
        }
        2 => {
            let path = d.str()?;
            let start = d.u64()?;
            let end = d.u64()?;
            let n_total = d.u64()?;
            let dim = d.u32()?;
            let hash = d.u64()?;
            ensure!(!path.is_empty(), "cache path must be non-empty");
            ensure!(
                start < end,
                "cache row range [{start}, {end}) is empty or inverted"
            );
            ensure!(
                end <= n_total,
                "cache row range end {end} exceeds n_total {n_total}"
            );
            ensure!(dim >= 1, "cache dimension must be ≥ 1");
            DataSpec::Cache {
                path,
                start,
                end,
                n_total,
                dim,
                hash,
            }
        }
        t => bail!("unknown data spec kind {t}"),
    };
    let balance = match d.u8()? {
        0 => Balance::Rows,
        1 => Balance::Nnz,
        b => bail!("unknown balance mode {b}"),
    };
    Ok(ProblemSpec {
        worker,
        machines,
        seed,
        part_seed,
        sp,
        local_threads,
        data,
        loss,
        solver,
        balance,
    })
}

fn put_eval(e: &mut Enc, op: &EvalOp) {
    match op {
        EvalOp::LossSumAt(w) => {
            e.u8(0);
            e.f64s(w);
        }
        EvalOp::ConjSum => e.u8(1),
        EvalOp::GradOracle(w) => {
            e.u8(2);
            e.f64s(w);
        }
        EvalOp::LossSumAtCurrent => e.u8(3),
        EvalOp::GapSums => e.u8(4),
    }
}

fn take_eval(d: &mut Dec<'_>) -> Result<EvalOp> {
    Ok(match d.u8()? {
        0 => EvalOp::LossSumAt(d.f64s()?),
        1 => EvalOp::ConjSum,
        2 => EvalOp::GradOracle(d.f64s()?),
        3 => EvalOp::LossSumAtCurrent,
        4 => EvalOp::GapSums,
        t => bail!("unknown eval op {t}"),
    })
}

fn write_framed<W: Write>(w: &mut W, tag: u8, payload: &[u8]) -> CommResult<usize> {
    if payload.len() > MAX_FRAME_LEN as usize {
        return Err(WireError::FrameTooLarge { len: payload.len() }.into());
    }
    w.write_all(&[tag])?;
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    Ok(FRAME_HEADER_BYTES + payload.len())
}

/// Encode a `LocalStep` frame straight from borrowed buffers (the
/// per-round hot path — no owned [`WireBroadcast`] clone). Byte-for-byte
/// identical to encoding [`Frame::LocalStep`].
pub fn write_local_step<W: Write>(
    w: &mut W,
    lambda: f64,
    b: BroadcastRef<'_>,
    flags: StepFlags,
    codec: DeltaCodec,
) -> CommResult<usize> {
    let mut e = Enc::default();
    e.f64(lambda);
    put_broadcast(&mut e, b);
    e.u8(flags.to_byte());
    put_trailing_codec(&mut e, codec);
    write_framed(w, TAG_LOCAL_STEP, &e.finish()?)
}

/// Encode an `Eval` frame with its fused broadcast from borrowed buffers
/// (see [`write_local_step`]).
pub fn write_eval<W: Write>(w: &mut W, op: &EvalOp, b: BroadcastRef<'_>) -> CommResult<usize> {
    let mut e = Enc::default();
    put_eval(&mut e, op);
    put_broadcast(&mut e, b);
    write_framed(w, TAG_EVAL, &e.finish()?)
}

/// Encode a `Broadcast` frame from borrowed buffers (see
/// [`write_local_step`]).
pub fn write_broadcast<W: Write>(w: &mut W, b: BroadcastRef<'_>) -> CommResult<usize> {
    let mut e = Enc::default();
    put_broadcast(&mut e, b);
    write_framed(w, TAG_BROADCAST, &e.finish()?)
}

impl Frame {
    /// Serialize onto `w`; returns the exact number of bytes written
    /// (header + payload) — the quantity the wire-byte accounting records.
    pub fn write_to<W: Write>(&self, w: &mut W) -> CommResult<usize> {
        let mut e = Enc::default();
        let tag = match self {
            Frame::Hello { magic, version } => {
                e.buf.extend_from_slice(magic);
                e.u16(*version);
                TAG_HELLO
            }
            Frame::Welcome {
                version,
                worker_id,
                machines,
            } => {
                e.u16(*version);
                e.u32(*worker_id);
                e.u32(*machines);
                TAG_WELCOME
            }
            Frame::AssignPartition(spec) => {
                put_spec(&mut e, spec);
                TAG_ASSIGN
            }
            Frame::LocalStep {
                lambda,
                broadcast,
                flags,
                codec,
            } => {
                e.f64(*lambda);
                put_broadcast(&mut e, broadcast.to_ref());
                e.u8(flags.to_byte());
                put_trailing_codec(&mut e, *codec);
                TAG_LOCAL_STEP
            }
            Frame::DeltaReply {
                delta,
                elapsed_secs,
                loss_sum,
                conj_sum,
                codec,
            } => {
                put_delta(&mut e, delta, *codec);
                e.f64(*elapsed_secs);
                let flags = (loss_sum.is_some() as u8) * STEP_FLAG_EVAL_LOSS
                    | (conj_sum.is_some() as u8) * STEP_FLAG_WANT_CONJ;
                e.u8(flags);
                if let Some(c) = conj_sum {
                    e.f64(*c);
                }
                if let Some(l) = loss_sum {
                    e.f64(*l);
                }
                put_trailing_codec(&mut e, *codec);
                TAG_DELTA_REPLY
            }
            Frame::Broadcast(b) => {
                put_broadcast(&mut e, b.to_ref());
                TAG_BROADCAST
            }
            Frame::SetReg(reg) => {
                put_reg(&mut e, reg);
                TAG_SET_REG
            }
            Frame::Eval { op, broadcast } => {
                put_eval(&mut e, op);
                put_broadcast(&mut e, broadcast.to_ref());
                TAG_EVAL
            }
            Frame::GapReply {
                loss_sum,
                conj_sum,
            } => {
                e.f64(*loss_sum);
                e.f64(*conj_sum);
                TAG_GAP_REPLY
            }
            Frame::Scalar(x) => {
                e.f64(*x);
                TAG_SCALAR
            }
            Frame::Vector { v, elapsed_secs } => {
                e.f64s(v);
                e.f64(*elapsed_secs);
                TAG_VECTOR
            }
            Frame::Ack => TAG_ACK,
            Frame::Shutdown => TAG_SHUTDOWN,
            Frame::Error { message } => {
                e.str(message);
                TAG_ERROR
            }
            Frame::Heartbeat => TAG_HEARTBEAT,
            Frame::HeartbeatAck => TAG_HEARTBEAT_ACK,
            Frame::Rejoin {
                worker_id,
                spec,
                expect_v,
                replay,
            } => {
                e.u32(*worker_id);
                put_spec(&mut e, spec);
                e.f64s(expect_v);
                e.bytes(replay);
                TAG_REJOIN
            }
        };
        write_framed(w, tag, &e.finish()?)
    }

    /// Read one frame; `Err` (never a panic) on truncation, unknown
    /// tags, oversized lengths, or any payload inconsistency. The second
    /// tuple element is the exact number of bytes consumed.
    pub fn read_from<R: Read>(r: &mut R) -> CommResult<(Frame, usize)> {
        let mut payload = Vec::new();
        Self::read_from_reusing(r, &mut payload)
    }

    /// [`Frame::read_from`] with a caller-owned payload scratch buffer —
    /// the per-connection hot path reuses one buffer across frames
    /// instead of allocating `len` bytes per message.
    pub fn read_from_reusing<R: Read>(
        r: &mut R,
        payload: &mut Vec<u8>,
    ) -> CommResult<(Frame, usize)> {
        let mut header = [0u8; FRAME_HEADER_BYTES];
        r.read_exact(&mut header)?;
        // Parse the header through `Dec` like any other payload — no
        // indexing, no infallible-by-argument conversions.
        let mut h = Dec::new(&header);
        let tag = h.u8()?;
        let len = h.u32()?;
        ensure!(
            len <= MAX_FRAME_LEN,
            "frame length {len} exceeds protocol cap {MAX_FRAME_LEN}"
        );
        payload.clear();
        payload.resize(len as usize, 0);
        r.read_exact(payload)?;
        let frame = Self::decode(tag, payload)?;
        Ok((frame, FRAME_HEADER_BYTES + len as usize))
    }

    fn decode(tag: u8, payload: &[u8]) -> Result<Frame> {
        let mut d = Dec::new(payload);
        let frame = match tag {
            TAG_HELLO => Frame::Hello {
                magic: d.le_bytes()?,
                version: d.u16()?,
            },
            TAG_WELCOME => Frame::Welcome {
                version: d.u16()?,
                worker_id: d.u32()?,
                machines: d.u32()?,
            },
            TAG_ASSIGN => Frame::AssignPartition(Box::new(take_spec(&mut d)?)),
            TAG_LOCAL_STEP => {
                let lambda = d.f64()?;
                let broadcast = take_broadcast(&mut d)?;
                // v2 payloads end here; v3 appends the flags byte, v4
                // the codec byte.
                let flags = if d.buf.is_empty() {
                    StepFlags::default()
                } else {
                    StepFlags::from_byte(d.u8()?)?
                };
                let codec = if d.buf.is_empty() {
                    DeltaCodec::F64
                } else {
                    take_codec(d.u8()?)?
                };
                Frame::LocalStep {
                    lambda,
                    broadcast,
                    flags,
                    codec,
                }
            }
            TAG_DELTA_REPLY => {
                let (delta, kind_codec) = take_delta(&mut d)?;
                let elapsed_secs = d.f64()?;
                // v2 payloads end here; v3 appends flags + the scalars,
                // v4 the codec byte.
                let (loss_sum, conj_sum) = if d.buf.is_empty() {
                    (None, None)
                } else {
                    let flags = StepFlags::from_byte(d.u8()?)?;
                    let conj = if flags.want_conj { Some(d.f64()?) } else { None };
                    let loss = if flags.eval_loss { Some(d.f64()?) } else { None };
                    (loss, conj)
                };
                // A trailing codec byte must agree with the (already
                // codec-describing) delta kind; when absent, the kind
                // alone carries the codec — v3-shaped payloads use f64
                // kinds, so they decode unchanged.
                let codec = if d.buf.is_empty() {
                    kind_codec
                } else {
                    let c = take_codec(d.u8()?)?;
                    ensure!(
                        c == kind_codec,
                        "delta codec byte says {c:?} but the delta kind is {kind_codec:?}"
                    );
                    c
                };
                Frame::DeltaReply {
                    delta,
                    elapsed_secs,
                    loss_sum,
                    conj_sum,
                    codec,
                }
            }
            TAG_BROADCAST => Frame::Broadcast(take_broadcast(&mut d)?),
            TAG_SET_REG => Frame::SetReg(take_reg(&mut d)?),
            TAG_EVAL => {
                let op = take_eval(&mut d)?;
                // v2 payloads end here; v3 appends the fused broadcast.
                let broadcast = if d.buf.is_empty() {
                    WireBroadcast::Empty
                } else {
                    take_broadcast(&mut d)?
                };
                Frame::Eval { op, broadcast }
            }
            TAG_GAP_REPLY => Frame::GapReply {
                loss_sum: d.f64()?,
                conj_sum: d.f64()?,
            },
            TAG_SCALAR => Frame::Scalar(d.f64()?),
            TAG_VECTOR => Frame::Vector {
                v: d.f64s()?,
                elapsed_secs: d.f64()?,
            },
            TAG_ACK => Frame::Ack,
            TAG_SHUTDOWN => Frame::Shutdown,
            TAG_ERROR => Frame::Error { message: d.str()? },
            TAG_HEARTBEAT => Frame::Heartbeat,
            TAG_HEARTBEAT_ACK => Frame::HeartbeatAck,
            TAG_REJOIN => Frame::Rejoin {
                worker_id: d.u32()?,
                spec: Box::new(take_spec(&mut d)?),
                expect_v: d.f64s()?,
                replay: d.bytes()?,
            },
            t => bail!("unknown frame tag {t}"),
        };
        d.finish()?;
        Ok(frame)
    }

    /// Validate a worker greeting; version/magic mismatches are `Err`
    /// (the version gate is a typed [`WireError::VersionSkew`] so the
    /// transport layer can surface it as such).
    pub fn expect_hello(&self) -> Result<()> {
        match self {
            Frame::Hello { magic, version } => {
                ensure!(
                    *magic == WIRE_MAGIC,
                    "bad protocol magic {magic:?} (expected {WIRE_MAGIC:?})"
                );
                if *version != WIRE_VERSION {
                    return Err(WireError::VersionSkew {
                        got: *version,
                        want: WIRE_VERSION,
                    });
                }
                Ok(())
            }
            other => bail!("expected Hello, got {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::sparse::codec_image;
    use crate::testing::prop::{for_each_case, Gen};
    use std::io::Cursor;

    fn encode(f: &Frame) -> Vec<u8> {
        let mut buf = Vec::new();
        let n = f.write_to(&mut buf).unwrap();
        assert_eq!(n, buf.len(), "write_to must report exact bytes");
        buf
    }

    fn roundtrip(f: &Frame) -> Frame {
        let bytes = encode(f);
        let (decoded, consumed) = Frame::read_from(&mut Cursor::new(&bytes)).unwrap();
        assert_eq!(consumed, bytes.len(), "read_from must report exact bytes");
        // Re-encoding the decoded frame must be byte-identical — the
        // equality notion that matters on a wire.
        assert_eq!(encode(&decoded), bytes, "re-encode differs for {f:?}");
        decoded
    }

    fn gen_codec(g: &mut Gen) -> DeltaCodec {
        match g.usize_in(0, 3) {
            0 => DeltaCodec::F64,
            1 => DeltaCodec::F32,
            _ => DeltaCodec::I16,
        }
    }

    /// Replace a delta's values with their codec images — what a real
    /// (error-feedback) sender transmits — so compressed roundtrips are
    /// byte-exact.
    fn quantize_values(delta: &mut Delta, codec: DeltaCodec) {
        let vals = match delta {
            Delta::Dense(v) => v,
            Delta::Sparse(s) => &mut s.val,
        };
        let step = i16_step(max_abs(vals));
        for v in vals.iter_mut() {
            *v = codec_image(codec, *v, step);
        }
    }

    fn gen_broadcast(g: &mut Gen) -> WireBroadcast {
        match g.usize_in(0, 4) {
            0 => WireBroadcast::Empty,
            1 => {
                let n = g.usize_in(0, 12);
                let mut idx: Vec<u32> = (0..n).map(|_| g.usize_in(0, 64) as u32).collect();
                idx.sort_unstable();
                idx.dedup();
                let val = g.vec_f64(idx.len(), -5.0, 5.0);
                WireBroadcast::SparseSet { idx, val }
            }
            2 => WireBroadcast::DenseSet(g.vec_f64(g.usize_in(0, 16), -5.0, 5.0)),
            _ => {
                let codec = gen_codec(g);
                let mut delta = gen_delta(g);
                quantize_values(&mut delta, codec);
                WireBroadcast::Add { delta, codec }
            }
        }
    }

    fn gen_delta(g: &mut Gen) -> Delta {
        if g.bool(0.5) {
            // Dense, including the empty vector.
            Delta::Dense(g.vec_f64(g.usize_in(0, 20), -3.0, 3.0))
        } else {
            // Sparse, including the empty (nnz = 0) delta.
            let dim = g.usize_in(1, 40);
            let nnz = g.usize_in(0, dim.min(8) + 1);
            let mut idx: Vec<u32> = g
                .rng()
                .sample_indices(dim, nnz)
                .into_iter()
                .map(|j| j as u32)
                .collect();
            idx.sort_unstable();
            let val = g.vec_f64(idx.len(), -3.0, 3.0);
            Delta::Sparse(SparseDelta { dim, idx, val })
        }
    }

    fn gen_spec(g: &mut Gen) -> ProblemSpec {
        let machines = g.usize_in(1, 8) as u32;
        let kind = g.usize_in(0, 3);
        let data = if kind == 0 {
            DataSpec::Synthetic(SyntheticSpec {
                name: "prop".into(),
                n: g.usize_in(8, 200),
                d: g.usize_in(1, 32),
                density: g.f64_in(0.05, 1.0),
                signal_density: g.f64_in(0.05, 1.0),
                noise: g.f64_in(0.0, 0.4),
                seed: g.rng().next_u64(),
            })
        } else if kind == 2 {
            let n_total = g.usize_in(2, 500) as u64;
            let start = g.usize_in(0, n_total as usize - 1) as u64;
            let end = g.usize_in(start as usize + 1, n_total as usize + 1) as u64;
            DataSpec::Cache {
                path: "/tmp/prop.dadmcache".into(),
                start,
                end,
                n_total,
                dim: g.usize_in(1, 64) as u32,
                hash: g.rng().next_u64(),
            }
        } else {
            let dim = g.usize_in(1, 16) as u32;
            let n_rows = g.usize_in(0, 6);
            let rows: Vec<Vec<(u32, f64)>> = (0..n_rows)
                .map(|_| {
                    let nnz = g.usize_in(0, dim as usize + 1);
                    let mut cols = g.rng().sample_indices(dim as usize, nnz);
                    cols.sort_unstable();
                    cols.into_iter()
                        .map(|j| (j as u32, g.f64_in(-2.0, 2.0)))
                        .collect()
                })
                .collect();
            DataSpec::Shard {
                n_total: g.usize_in(n_rows.max(1), 500) as u64,
                dim,
                global_indices: (0..n_rows as u64).collect(),
                y: g.vec_f64(n_rows, -1.0, 1.0),
                rows,
            }
        };
        ProblemSpec {
            worker: g.usize_in(0, machines as usize) as u32,
            machines,
            seed: g.rng().next_u64(),
            part_seed: g.rng().next_u64(),
            sp: g.f64_in(0.01, 1.0),
            local_threads: g.usize_in(1, 5) as u32,
            data,
            loss: match g.usize_in(0, 4) {
                0 => WireLoss::SmoothHinge(SmoothHinge::new(g.f64_log_in(1e-6, 10.0))),
                1 => WireLoss::Logistic,
                2 => WireLoss::Hinge,
                _ => WireLoss::Squared,
            },
            balance: if g.bool(0.5) {
                Balance::Nnz
            } else {
                Balance::Rows
            },
            solver: if g.bool(0.5) {
                WireSolver::ProxSdca
            } else {
                WireSolver::Theorem {
                    radius: g.f64_in(0.1, 4.0),
                }
            },
        }
    }

    fn gen_flags(g: &mut Gen) -> StepFlags {
        StepFlags {
            eval_loss: g.bool(0.5),
            want_conj: g.bool(0.5),
            resum_conj: g.bool(0.5),
        }
    }

    #[test]
    fn prop_every_frame_roundtrips() {
        for_each_case(0x71C9, 170, |g| {
            let frame = match g.usize_in(0, 17) {
                0 => Frame::Hello {
                    magic: WIRE_MAGIC,
                    version: WIRE_VERSION,
                },
                1 => Frame::Welcome {
                    version: WIRE_VERSION,
                    worker_id: g.usize_in(0, 64) as u32,
                    machines: g.usize_in(1, 64) as u32,
                },
                2 => Frame::AssignPartition(Box::new(gen_spec(g))),
                3 => Frame::LocalStep {
                    lambda: g.f64_log_in(1e-9, 1.0),
                    broadcast: gen_broadcast(g),
                    flags: gen_flags(g),
                    codec: gen_codec(g),
                },
                4 => {
                    let codec = gen_codec(g);
                    let mut delta = gen_delta(g);
                    quantize_values(&mut delta, codec);
                    Frame::DeltaReply {
                        delta,
                        elapsed_secs: g.f64_in(0.0, 1.0),
                        loss_sum: g.bool(0.5).then(|| g.f64_in(-10.0, 1e4)),
                        conj_sum: g.bool(0.5).then(|| g.f64_in(-1e4, 1e4)),
                        codec,
                    }
                }
                5 => Frame::Broadcast(gen_broadcast(g)),
                6 => Frame::SetReg(if g.bool(0.5) {
                    WireReg::ElasticNet(ElasticNet::new(g.f64_in(0.0, 2.0)))
                } else {
                    WireReg::Shifted(ShiftedElasticNet::new(
                        ElasticNet::new(g.f64_in(0.0, 2.0)),
                        g.vec_f64(g.usize_in(0, 10), -2.0, 2.0),
                    ))
                }),
                7 => Frame::Eval {
                    op: match g.usize_in(0, 5) {
                        0 => EvalOp::LossSumAt(g.vec_f64(g.usize_in(0, 12), -2.0, 2.0)),
                        1 => EvalOp::ConjSum,
                        2 => EvalOp::GradOracle(g.vec_f64(g.usize_in(0, 12), -2.0, 2.0)),
                        3 => EvalOp::LossSumAtCurrent,
                        _ => EvalOp::GapSums,
                    },
                    broadcast: gen_broadcast(g),
                },
                8 => Frame::Scalar(g.f64_in(-1e6, 1e6)),
                9 => Frame::Vector {
                    v: g.vec_f64(g.usize_in(0, 20), -10.0, 10.0),
                    elapsed_secs: g.f64_in(0.0, 2.0),
                },
                10 => Frame::Ack,
                11 => Frame::Shutdown,
                12 => Frame::GapReply {
                    loss_sum: g.f64_in(0.0, 1e5),
                    conj_sum: g.f64_in(-1e5, 1e5),
                },
                13 => Frame::Error {
                    message: "ü message with µnicode".into(),
                },
                14 => Frame::Heartbeat,
                15 => Frame::HeartbeatAck,
                _ => Frame::Rejoin {
                    worker_id: g.usize_in(0, 64) as u32,
                    spec: Box::new(gen_spec(g)),
                    expect_v: g.vec_f64(g.usize_in(0, 12), -3.0, 3.0),
                    replay: g.bytes(g.usize_in(0, 48)),
                },
            };
            roundtrip(&frame);
        });
    }

    #[test]
    fn v2_shaped_payloads_still_decode() {
        // A v2 LocalStep payload ends after the broadcast (no flags
        // byte); v3 decoders must read it as all-false flags.
        let mut e = Vec::new();
        write_local_step(
            &mut e,
            1e-3,
            BroadcastRef::DenseSet(&[1.0, 2.0]),
            StepFlags::default(),
            DeltaCodec::F64,
        )
        .unwrap();
        // Strip the trailing flags byte and fix the length prefix.
        let mut v2 = e[..e.len() - 1].to_vec();
        let len = (v2.len() - FRAME_HEADER_BYTES) as u32;
        v2[1..5].copy_from_slice(&len.to_le_bytes());
        let (frame, _) = Frame::read_from(&mut Cursor::new(&v2)).unwrap();
        match frame {
            Frame::LocalStep { flags, .. } => assert_eq!(flags, StepFlags::default()),
            other => panic!("expected LocalStep, got {other:?}"),
        }

        // A v2 DeltaReply payload ends after elapsed_secs.
        let full = encode(&Frame::DeltaReply {
            delta: Delta::Dense(vec![0.5, -1.0]),
            elapsed_secs: 0.25,
            loss_sum: None,
            conj_sum: None,
            codec: DeltaCodec::F64,
        });
        let mut v2 = full[..full.len() - 1].to_vec(); // drop the flags byte
        let len = (v2.len() - FRAME_HEADER_BYTES) as u32;
        v2[1..5].copy_from_slice(&len.to_le_bytes());
        let (frame, _) = Frame::read_from(&mut Cursor::new(&v2)).unwrap();
        match frame {
            Frame::DeltaReply {
                loss_sum, conj_sum, ..
            } => {
                assert_eq!(loss_sum, None);
                assert_eq!(conj_sum, None);
            }
            other => panic!("expected DeltaReply, got {other:?}"),
        }

        // A v2 Eval payload ends after the op (no fused broadcast).
        let full = encode(&Frame::Eval {
            op: EvalOp::ConjSum,
            broadcast: WireBroadcast::Empty,
        });
        let mut v2 = full[..full.len() - 1].to_vec(); // drop the Empty broadcast byte
        let len = (v2.len() - FRAME_HEADER_BYTES) as u32;
        v2[1..5].copy_from_slice(&len.to_le_bytes());
        let (frame, _) = Frame::read_from(&mut Cursor::new(&v2)).unwrap();
        match frame {
            Frame::Eval { broadcast, .. } => {
                assert!(matches!(broadcast, WireBroadcast::Empty))
            }
            other => panic!("expected Eval, got {other:?}"),
        }
    }

    #[test]
    fn delta_reply_telemetry_roundtrips_bitwise() {
        let f = Frame::DeltaReply {
            delta: Delta::Dense(vec![1.0]),
            elapsed_secs: 0.5,
            loss_sum: Some(3.5000000000000004),
            conj_sum: Some(-2.25),
            codec: DeltaCodec::F64,
        };
        match roundtrip(&f) {
            Frame::DeltaReply {
                loss_sum, conj_sum, ..
            } => {
                assert_eq!(loss_sum.unwrap().to_bits(), 3.5000000000000004f64.to_bits());
                assert_eq!(conj_sum.unwrap().to_bits(), (-2.25f64).to_bits());
            }
            other => panic!("expected DeltaReply, got {other:?}"),
        }
        // Unknown flag bits are a decode error, not a silent skip.
        let mut bytes = encode(&f);
        let flag_pos = bytes.len() - 17; // flags byte precedes the two f64s
        bytes[flag_pos] |= 1 << 7;
        assert!(Frame::read_from(&mut Cursor::new(&bytes)).is_err());
    }

    #[test]
    fn compressed_delta_replies_roundtrip_and_shrink() {
        let dim = 1000usize;
        let idx: Vec<u32> = (0..200u32).map(|j| j * 5).collect();
        let raw: Vec<f64> = (0..200).map(|i| (i as f64 - 100.0) * 0.01).collect();
        let step = i16_step(max_abs(&raw));
        let mut lens = Vec::new();
        for codec in [DeltaCodec::F64, DeltaCodec::F32, DeltaCodec::I16] {
            let val: Vec<f64> = raw.iter().map(|&v| codec_image(codec, v, step)).collect();
            let f = Frame::DeltaReply {
                delta: Delta::Sparse(SparseDelta {
                    dim,
                    idx: idx.clone(),
                    val: val.clone(),
                }),
                elapsed_secs: 0.25,
                loss_sum: None,
                conj_sum: None,
                codec,
            };
            // Roundtrip (which also pins re-encode byte-stability — the
            // i16 step re-derivation from images must be canonical) and
            // check every image survives the wire bit for bit.
            match roundtrip(&f) {
                Frame::DeltaReply {
                    delta: Delta::Sparse(s),
                    codec: c,
                    ..
                } => {
                    assert_eq!(c, codec);
                    assert_eq!(s.idx, idx);
                    let got: Vec<u64> = s.val.iter().map(|v| v.to_bits()).collect();
                    let want: Vec<u64> = val.iter().map(|v| v.to_bits()).collect();
                    assert_eq!(got, want, "{codec:?} images must survive bit for bit");
                }
                other => panic!("expected sparse DeltaReply, got {other:?}"),
            }
            lens.push(encode(&f).len());
        }
        // Entry widths 12 / 8 / 6 bytes ⇒ strictly shrinking frames.
        assert!(
            lens[2] < lens[1] && lens[1] < lens[0],
            "frame sizes must shrink with the codec: {lens:?}"
        );
        // At nnz = 200 the i16 frame is comfortably near its 6/12
        // asymptote; allow slack for headers, step, and codec byte.
        assert!(
            lens[2] * 10 <= lens[0] * 6,
            "i16 frame {} not ≤ 0.6× f64 frame {}",
            lens[2],
            lens[0]
        );
    }

    #[test]
    fn v3_shaped_payloads_decode_as_exact_f64() {
        // An exact-f64 v4 frame writes *no* codec byte: its payload is
        // byte-identical to the v3 shape. Pin the exact length —
        // lambda (8) + dense-set broadcast (1 + 4 + 16) + flags (1).
        let mut ls = Vec::new();
        write_local_step(
            &mut ls,
            1e-3,
            BroadcastRef::DenseSet(&[1.0, 2.0]),
            StepFlags::default(),
            DeltaCodec::F64,
        )
        .unwrap();
        assert_eq!(ls.len(), FRAME_HEADER_BYTES + 8 + 21 + 1);

        // A compressed LocalStep carries exactly one extra byte...
        let mut ls_i16 = Vec::new();
        write_local_step(
            &mut ls_i16,
            1e-3,
            BroadcastRef::DenseSet(&[1.0, 2.0]),
            StepFlags::default(),
            DeltaCodec::I16,
        )
        .unwrap();
        assert_eq!(ls_i16.len(), ls.len() + 1);
        // ...and stripping it yields a v3-shaped payload that decodes
        // with the default codec.
        let mut v3 = ls_i16[..ls_i16.len() - 1].to_vec();
        let len = (v3.len() - FRAME_HEADER_BYTES) as u32;
        v3[1..5].copy_from_slice(&len.to_le_bytes());
        match Frame::read_from(&mut Cursor::new(&v3)).unwrap().0 {
            Frame::LocalStep { codec, .. } => assert_eq!(codec, DeltaCodec::F64),
            other => panic!("expected LocalStep, got {other:?}"),
        }

        // A compressed DeltaReply stripped of its trailing codec byte
        // still knows its codec — the delta kind byte carries it.
        let step = i16_step(3.0);
        let full = encode(&Frame::DeltaReply {
            delta: Delta::Dense(vec![codec_image(DeltaCodec::I16, 3.0, step)]),
            elapsed_secs: 0.5,
            loss_sum: None,
            conj_sum: None,
            codec: DeltaCodec::I16,
        });
        let mut v3 = full[..full.len() - 1].to_vec();
        let len = (v3.len() - FRAME_HEADER_BYTES) as u32;
        v3[1..5].copy_from_slice(&len.to_le_bytes());
        match Frame::read_from(&mut Cursor::new(&v3)).unwrap().0 {
            Frame::DeltaReply { codec, .. } => assert_eq!(codec, DeltaCodec::I16),
            other => panic!("expected DeltaReply, got {other:?}"),
        }
    }

    #[test]
    fn codec_kind_mismatch_and_bad_step_are_err() {
        let step = i16_step(1.0);
        let f = Frame::DeltaReply {
            delta: Delta::Dense(vec![codec_image(DeltaCodec::I16, 1.0, step)]),
            elapsed_secs: 0.5,
            loss_sum: None,
            conj_sum: None,
            codec: DeltaCodec::I16,
        };
        let mut bytes = encode(&f);
        let last = bytes.len() - 1;
        bytes[last] = 1; // trailing byte claims f32 over an i16-kind delta
        assert!(Frame::read_from(&mut Cursor::new(&bytes)).is_err());
        bytes[last] = 9; // unknown codec byte
        assert!(Frame::read_from(&mut Cursor::new(&bytes)).is_err());

        // A non-finite / non-positive i16 step is rejected before any
        // image is reconstructed.
        for bad in [0.0f64, -1.0, f64::NAN, f64::INFINITY] {
            let mut payload = vec![4u8]; // dense-i16 delta kind
            payload.extend_from_slice(&bad.to_le_bytes());
            payload.extend_from_slice(&1u32.to_le_bytes());
            payload.extend_from_slice(&5i16.to_le_bytes());
            payload.extend_from_slice(&0.5f64.to_le_bytes()); // elapsed
            payload.push(0); // flags
            payload.push(2); // codec = i16
            let mut frame = vec![TAG_DELTA_REPLY];
            frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
            frame.extend_from_slice(&payload);
            assert!(
                Frame::read_from(&mut Cursor::new(&frame)).is_err(),
                "step {bad} must be a decode error"
            );
        }
    }

    #[test]
    fn add_broadcast_roundtrips_images_bitwise() {
        let raw = [0.5, -0.25, 1.0];
        let step = i16_step(max_abs(&raw));
        let val: Vec<f64> = raw
            .iter()
            .map(|&v| codec_image(DeltaCodec::I16, v, step))
            .collect();
        let f = Frame::Broadcast(WireBroadcast::Add {
            delta: Delta::Sparse(SparseDelta {
                dim: 10,
                idx: vec![0, 3, 7],
                val: val.clone(),
            }),
            codec: DeltaCodec::I16,
        });
        match roundtrip(&f) {
            Frame::Broadcast(WireBroadcast::Add {
                delta: Delta::Sparse(s),
                codec,
            }) => {
                assert_eq!(codec, DeltaCodec::I16);
                assert_eq!(s.idx, vec![0, 3, 7]);
                let got: Vec<u64> = s.val.iter().map(|v| v.to_bits()).collect();
                let want: Vec<u64> = val.iter().map(|v| v.to_bits()).collect();
                assert_eq!(got, want, "add images must survive the wire bit for bit");
            }
            other => panic!("expected Add broadcast, got {other:?}"),
        }
    }

    #[test]
    fn empty_and_dense_fallback_deltas_roundtrip() {
        // The two boundary messages DESIGN.md §7 cares about: an empty
        // sparse delta (no coordinate touched) and the dense fallback.
        for delta in [
            Delta::Sparse(SparseDelta {
                dim: 100,
                idx: vec![],
                val: vec![],
            }),
            Delta::Dense(vec![0.5; 100]),
            Delta::Dense(vec![]),
        ] {
            let f = Frame::DeltaReply {
                delta,
                elapsed_secs: 0.25,
                loss_sum: None,
                conj_sum: Some(1.5),
                codec: DeltaCodec::F64,
            };
            roundtrip(&f);
        }
    }

    #[test]
    fn zero_copy_encoders_match_owned_frames() {
        let idx = vec![1u32, 5, 9];
        let val = vec![0.5, -1.0, 2.0];
        let flags = StepFlags {
            eval_loss: true,
            want_conj: true,
            resum_conj: false,
        };
        let owned = Frame::LocalStep {
            lambda: 1e-3,
            broadcast: WireBroadcast::SparseSet {
                idx: idx.clone(),
                val: val.clone(),
            },
            flags,
            codec: DeltaCodec::F64,
        };
        let mut borrowed = Vec::new();
        write_local_step(
            &mut borrowed,
            1e-3,
            BroadcastRef::SparseSet {
                idx: &idx,
                val: &val,
            },
            flags,
            DeltaCodec::F64,
        )
        .unwrap();
        assert_eq!(encode(&owned), borrowed);

        // The Add broadcast's borrowed form matches the owned form too
        // (the compressed hot path sends straight from the assembled
        // quantized delta).
        let step = i16_step(max_abs(&val));
        let qval: Vec<f64> = val
            .iter()
            .map(|&v| codec_image(DeltaCodec::I16, v, step))
            .collect();
        let add = Delta::Sparse(SparseDelta {
            dim: 16,
            idx: idx.clone(),
            val: qval,
        });
        let owned = Frame::LocalStep {
            lambda: 1e-3,
            broadcast: WireBroadcast::Add {
                delta: add.clone(),
                codec: DeltaCodec::I16,
            },
            flags,
            codec: DeltaCodec::I16,
        };
        let mut borrowed = Vec::new();
        write_local_step(
            &mut borrowed,
            1e-3,
            BroadcastRef::Add {
                delta: &add,
                codec: DeltaCodec::I16,
            },
            flags,
            DeltaCodec::I16,
        )
        .unwrap();
        assert_eq!(encode(&owned), borrowed);

        let owned = Frame::Eval {
            op: EvalOp::GapSums,
            broadcast: WireBroadcast::SparseSet {
                idx: idx.clone(),
                val: val.clone(),
            },
        };
        let mut borrowed = Vec::new();
        write_eval(
            &mut borrowed,
            &EvalOp::GapSums,
            BroadcastRef::SparseSet {
                idx: &idx,
                val: &val,
            },
        )
        .unwrap();
        assert_eq!(encode(&owned), borrowed);

        let dense = vec![1.0, 2.0, 3.0];
        let owned = Frame::Broadcast(WireBroadcast::DenseSet(dense.clone()));
        let mut borrowed = Vec::new();
        write_broadcast(&mut borrowed, BroadcastRef::DenseSet(&dense)).unwrap();
        assert_eq!(encode(&owned), borrowed);
    }

    #[test]
    fn prop_truncation_is_err_never_panic() {
        for_each_case(0x7A61, 80, |g| {
            let codec = gen_codec(g);
            let mut delta = gen_delta(g);
            quantize_values(&mut delta, codec);
            let frame = Frame::DeltaReply {
                delta,
                elapsed_secs: 0.1,
                loss_sum: g.bool(0.5).then_some(2.0),
                conj_sum: g.bool(0.5).then_some(-1.0),
                codec,
            };
            let bytes = encode(&frame);
            let cut = g.usize_in(0, bytes.len());
            if cut == bytes.len() {
                return;
            }
            assert!(
                Frame::read_from(&mut Cursor::new(&bytes[..cut])).is_err(),
                "truncated frame at {cut}/{} decoded",
                bytes.len()
            );
        });
    }

    #[test]
    fn prop_corrupted_frames_never_panic() {
        // Flipping any byte must yield Ok (benign payload flip) or Err —
        // never a panic or a huge allocation. for_each_case re-raises
        // panics, so reaching the end is the assertion.
        for_each_case(0xF177, 120, |g| {
            let frame = Frame::AssignPartition(Box::new(gen_spec(g)));
            let mut bytes = encode(&frame);
            let pos = g.usize_in(0, bytes.len());
            let bit = g.usize_in(0, 8);
            bytes[pos] ^= 1 << bit;
            let _ = Frame::read_from(&mut Cursor::new(&bytes));
        });
    }

    #[test]
    fn prop_random_garbage_never_panics() {
        for_each_case(0x6A5B, 150, |g| {
            let n = g.usize_in(0, 64);
            let bytes = g.bytes(n);
            let _ = Frame::read_from(&mut Cursor::new(&bytes));
        });
    }

    #[test]
    fn unknown_tag_and_oversized_length_are_err() {
        // Unknown tag.
        let bad_tag = [200u8, 0, 0, 0, 0];
        assert!(Frame::read_from(&mut Cursor::new(&bad_tag)).is_err());
        // Length prefix past the protocol cap — must be rejected before
        // any allocation.
        let mut oversized = vec![TAG_ACK];
        oversized.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(Frame::read_from(&mut Cursor::new(&oversized)).is_err());
        // Trailing garbage after a valid payload.
        let trailing = vec![TAG_ACK, 3, 0, 0, 0, 1, 2, 3];
        assert!(Frame::read_from(&mut Cursor::new(&trailing)).is_err());
        // Inflated element count inside a well-formed frame.
        let mut inflated = vec![TAG_SCALAR];
        inflated.extend_from_slice(&4u32.to_le_bytes());
        inflated.extend_from_slice(&[1, 2, 3, 4]); // not 8 bytes of f64
        assert!(Frame::read_from(&mut Cursor::new(&inflated)).is_err());
    }

    #[test]
    fn version_and_magic_mismatch_are_err() {
        Frame::Hello {
            magic: WIRE_MAGIC,
            version: WIRE_VERSION,
        }
        .expect_hello()
        .unwrap();
        assert!(Frame::Hello {
            magic: WIRE_MAGIC,
            version: WIRE_VERSION + 1,
        }
        .expect_hello()
        .is_err());
        assert!(Frame::Hello {
            magic: *b"XXXX",
            version: WIRE_VERSION,
        }
        .expect_hello()
        .is_err());
        assert!(Frame::Ack.expect_hello().is_err());
    }

    #[test]
    fn shard_spec_carries_exactly_one_machine() {
        let data = crate::data::synthetic::tiny_classification(30, 6, 5);
        let part = Partition::balanced(30, 3, 5);
        let spec = shard_data_spec(&data, &part, 1);
        match &spec {
            DataSpec::Shard {
                n_total,
                dim,
                global_indices,
                rows,
                y,
            } => {
                assert_eq!(*n_total, 30);
                assert_eq!(*dim, 6);
                assert_eq!(rows.len(), part.shard_size(1));
                assert_eq!(y.len(), rows.len());
                assert_eq!(global_indices.len(), rows.len());
            }
            _ => panic!("expected shard spec"),
        }
    }

    #[test]
    fn oversized_count_is_latched_not_panicked() {
        // A count beyond u32 must surface as `WireError`, never a panic
        // (the pre-PR-6 encoder `expect`ed here).
        let mut e = Enc::default();
        let too_big = u32::MAX as usize + 1;
        e.count(too_big);
        e.count(too_big + 7); // sticky: first offender is reported
        match e.finish() {
            Err(WireError::CollectionTooLarge { len }) => assert_eq!(len, too_big),
            other => panic!("expected CollectionTooLarge, got {other:?}"),
        }
    }

    #[test]
    fn in_range_counts_finish_clean() {
        let mut e = Enc::default();
        e.f64s(&[1.0, 2.0, 3.0]);
        e.str("ok");
        let payload = e.finish().unwrap();
        let mut d = Dec::new(&payload);
        assert_eq!(d.f64s().unwrap(), vec![1.0, 2.0, 3.0]);
        assert_eq!(d.str().unwrap(), "ok");
        d.finish().unwrap();
    }

    #[test]
    fn wire_error_messages_name_the_size() {
        let c = WireError::CollectionTooLarge { len: 5_000_000_000 };
        assert!(format!("{c}").contains("5000000000"));
        let f = WireError::FrameTooLarge { len: 7 };
        assert!(format!("{f}").contains("7"));
        // Boxes as a std error object (what lets non-comm callers `?`
        // these into their own error types).
        let err: Box<dyn std::error::Error> = Box::new(c);
        assert!(format!("{err}").contains("collection too large"));
    }

    #[test]
    fn heartbeat_frames_are_empty_payload() {
        // The liveness pair must cost exactly one frame header each —
        // they fire on otherwise-idle connections and must not perturb
        // the wire-byte accounting by more than the minimum.
        assert_eq!(encode(&Frame::Heartbeat).len(), FRAME_HEADER_BYTES);
        assert_eq!(encode(&Frame::HeartbeatAck).len(), FRAME_HEADER_BYTES);
        roundtrip(&Frame::Heartbeat);
        roundtrip(&Frame::HeartbeatAck);
    }

    #[test]
    fn rejoin_carries_spec_expectation_and_replay_verbatim() {
        // The replay blob is a concatenation of *real* encoded frames —
        // exactly what the coordinator's replay log holds — and must
        // survive the wire byte-for-byte so the replacement worker
        // re-handles the identical byte sequence.
        let mut replay = Vec::new();
        Frame::SetReg(WireReg::ElasticNet(ElasticNet::new(0.25)))
            .write_to(&mut replay)
            .unwrap();
        write_local_step(
            &mut replay,
            1e-3,
            BroadcastRef::DenseSet(&[1.0, -2.0]),
            StepFlags::default(),
            DeltaCodec::F64,
        )
        .unwrap();
        let f = Frame::Rejoin {
            worker_id: 2,
            spec: Box::new(ProblemSpec {
                worker: 2,
                machines: 4,
                seed: 0xDAD_A,
                part_seed: 11,
                sp: 0.2,
                local_threads: 1,
                data: DataSpec::Synthetic(SyntheticSpec {
                    name: "rejoin".into(),
                    n: 64,
                    d: 8,
                    density: 0.5,
                    signal_density: 0.5,
                    noise: 0.1,
                    seed: 7,
                }),
                loss: WireLoss::Logistic,
                solver: WireSolver::ProxSdca,
                balance: Balance::Rows,
            }),
            expect_v: vec![0.5, -0.25, 1.0 + f64::EPSILON],
            replay: replay.clone(),
        };
        match roundtrip(&f) {
            Frame::Rejoin {
                worker_id,
                expect_v,
                replay: got,
                ..
            } => {
                assert_eq!(worker_id, 2);
                let bits: Vec<u64> = expect_v.iter().map(|v| v.to_bits()).collect();
                let want: Vec<u64> = [0.5, -0.25, 1.0 + f64::EPSILON]
                    .iter()
                    .map(|v| v.to_bits())
                    .collect();
                assert_eq!(bits, want, "expect_v must survive bit for bit");
                assert_eq!(got, replay, "replay log must travel verbatim");
                // The carried log itself decodes back into the frames.
                let mut cur = Cursor::new(&got);
                let (f1, _) = Frame::read_from(&mut cur).unwrap();
                assert!(matches!(f1, Frame::SetReg(_)));
                let (f2, _) = Frame::read_from(&mut cur).unwrap();
                assert!(matches!(f2, Frame::LocalStep { .. }));
            }
            other => panic!("expected Rejoin, got {other:?}"),
        }
    }

    #[test]
    fn prop_rejoin_truncation_and_corruption_never_panic() {
        for_each_case(0x5E10, 80, |g| {
            let frame = Frame::Rejoin {
                worker_id: g.usize_in(0, 16) as u32,
                spec: Box::new(gen_spec(g)),
                expect_v: g.vec_f64(g.usize_in(0, 10), -2.0, 2.0),
                replay: g.bytes(g.usize_in(0, 40)),
            };
            let mut bytes = encode(&frame);
            if g.bool(0.5) {
                let cut = g.usize_in(0, bytes.len());
                if cut == bytes.len() {
                    return;
                }
                assert!(
                    Frame::read_from(&mut Cursor::new(&bytes[..cut])).is_err(),
                    "truncated Rejoin at {cut}/{} decoded",
                    bytes.len()
                );
            } else {
                let pos = g.usize_in(0, bytes.len());
                let bit = g.usize_in(0, 8);
                bytes[pos] ^= 1 << bit;
                let _ = Frame::read_from(&mut Cursor::new(&bytes));
            }
        });
    }

    #[test]
    fn v4_shaped_payloads_still_decode_under_v5() {
        // v5 added frames, not payload bytes: every v4 shape must decode
        // unchanged. Exercise one frame of each direction-critical kind
        // and pin that the encoded bytes contain no v5 artifacts (the
        // tags stay below TAG_HEARTBEAT).
        let frames = [
            Frame::LocalStep {
                lambda: 1e-3,
                broadcast: WireBroadcast::DenseSet(vec![1.0, 2.0]),
                flags: StepFlags::default(),
                codec: DeltaCodec::F64,
            },
            Frame::DeltaReply {
                delta: Delta::Dense(vec![0.5]),
                elapsed_secs: 0.1,
                loss_sum: Some(2.0),
                conj_sum: Some(-1.0),
                codec: DeltaCodec::F64,
            },
            Frame::Broadcast(WireBroadcast::Empty),
            Frame::Eval {
                op: EvalOp::GapSums,
                broadcast: WireBroadcast::Empty,
            },
        ];
        for f in &frames {
            let bytes = encode(f);
            assert!(
                bytes.first().is_some_and(|&t| t < TAG_HEARTBEAT),
                "v4 frame encoded with a v5 tag: {f:?}"
            );
            roundtrip(f);
        }
        // The handshake gate: a v4 worker greeting against a v5
        // coordinator is a typed VersionSkew, not a string to parse.
        let hello = Frame::Hello {
            magic: WIRE_MAGIC,
            version: 4,
        };
        match hello.expect_hello() {
            Err(WireError::VersionSkew { got, want }) => {
                assert_eq!((got, want), (4, WIRE_VERSION));
            }
            other => panic!("expected VersionSkew, got {other:?}"),
        }
    }

    #[test]
    fn cache_spec_roundtrips_verbatim() {
        let spec = ProblemSpec {
            worker: 1,
            machines: 4,
            seed: 9,
            part_seed: 0,
            sp: 0.25,
            local_threads: 2,
            data: DataSpec::Cache {
                path: "/data/rcv1.dadmcache".into(),
                start: 100,
                end: 200,
                n_total: 400,
                dim: 47_236,
                hash: 0xFEED_FACE_CAFE_BEEF,
            },
            loss: WireLoss::Logistic,
            solver: WireSolver::ProxSdca,
            balance: Balance::Nnz,
        };
        match roundtrip(&Frame::AssignPartition(Box::new(spec))) {
            Frame::AssignPartition(got) => {
                assert_eq!(got.balance, Balance::Nnz, "balance must survive the wire");
                match got.data {
                    DataSpec::Cache {
                        path,
                        start,
                        end,
                        n_total,
                        dim,
                        hash,
                    } => {
                        assert_eq!(path, "/data/rcv1.dadmcache");
                        assert_eq!((start, end, n_total, dim), (100, 200, 400, 47_236));
                        assert_eq!(hash, 0xFEED_FACE_CAFE_BEEF);
                    }
                    other => panic!("expected cache spec, got {other:?}"),
                }
            }
            other => panic!("expected AssignPartition, got {other:?}"),
        }
    }

    #[test]
    fn cache_spec_rejects_bad_range_and_empty_path() {
        let bad_specs = [
            ("empty range", "/ok".to_string(), 5u64, 5u64, 10u64),
            ("inverted range", "/ok".to_string(), 7, 3, 10),
            ("end past n_total", "/ok".to_string(), 0, 11, 10),
            ("empty path", String::new(), 0, 5, 10),
        ];
        for (what, path, start, end, n_total) in bad_specs {
            let spec = ProblemSpec {
                worker: 0,
                machines: 1,
                seed: 0,
                part_seed: 0,
                sp: 0.5,
                local_threads: 1,
                data: DataSpec::Cache {
                    path,
                    start,
                    end,
                    n_total,
                    dim: 3,
                    hash: 1,
                },
                loss: WireLoss::Logistic,
                solver: WireSolver::ProxSdca,
                balance: Balance::Rows,
            };
            let mut e = Enc::default();
            put_spec(&mut e, &spec);
            let payload = e.finish().unwrap();
            let mut d = Dec::new(&payload);
            assert!(take_spec(&mut d).is_err(), "decoder accepted {what}");
        }
    }

    #[test]
    fn spec_kinds_unchanged_and_pre_v7_versions_rejected() {
        // v7 appended a trailing balance byte to every spec; the
        // `DataSpec` kinds 0/1 payload bodies are otherwise unchanged,
        // and the handshake gate keeps pre-v7 workers out.
        let mk = |data| ProblemSpec {
            worker: 0,
            machines: 2,
            seed: 1,
            part_seed: 2,
            sp: 0.5,
            local_threads: 1,
            data,
            loss: WireLoss::Logistic,
            solver: WireSolver::ProxSdca,
            balance: Balance::Rows,
        };
        let cases = [
            mk(DataSpec::Synthetic(SyntheticSpec {
                name: "v5".into(),
                n: 16,
                d: 4,
                density: 0.5,
                signal_density: 0.5,
                noise: 0.1,
                seed: 3,
            })),
            mk(DataSpec::Shard {
                n_total: 4,
                dim: 2,
                global_indices: vec![1, 3],
                rows: vec![vec![(0, 1.0)], vec![(1, -1.0)]],
                y: vec![1.0, -1.0],
            }),
        ];
        for (want_kind, spec) in [0u8, 1].into_iter().zip(cases) {
            let mut e = Enc::default();
            put_spec(&mut e, &spec);
            let payload = e.finish().unwrap();
            let mut d = Dec::new(&payload);
            let got = take_spec(&mut d).unwrap();
            d.finish().unwrap();
            match (want_kind, &got.data) {
                (0, DataSpec::Synthetic(s)) => assert_eq!(s.seed, 3),
                (1, DataSpec::Shard { y, .. }) => assert_eq!(y, &[1.0, -1.0]),
                (_, other) => panic!("spec kind {want_kind} changed meaning: {other:?}"),
            }
        }
        // A v6 worker greeting a v7 coordinator is a typed VersionSkew.
        match (Frame::Hello {
            magic: WIRE_MAGIC,
            version: 6,
        })
        .expect_hello()
        {
            Err(WireError::VersionSkew { got, want }) => {
                assert_eq!((got, want), (6, WIRE_VERSION));
            }
            other => panic!("expected VersionSkew, got {other:?}"),
        }
    }

    #[test]
    fn strictly_increasing_matches_windows_semantics() {
        assert!(strictly_increasing(&[]));
        assert!(strictly_increasing(&[3]));
        assert!(strictly_increasing(&[0, 1, 2, 9]));
        assert!(!strictly_increasing(&[0, 1, 1]));
        assert!(!strictly_increasing(&[2, 1]));
    }

    #[test]
    fn le_array_truncates_rather_than_panics() {
        // Total even on a (debug-asserted) caller bug in release builds.
        assert_eq!(le_array::<2>(&[0xAB, 0xCD]), [0xAB, 0xCD]);
        assert_eq!(le_array::<0>(&[]), [0u8; 0]);
    }
}
