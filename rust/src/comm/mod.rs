//! Simulated multi-machine substrate.
//!
//! The paper runs on an OpenMPI cluster with one process per machine
//! (§10: "we use one processor to simulate one machine"). We go one level
//! lighter: one *worker* per machine executed by a persistent thread
//! [`pool`] ([`cluster`] selects the backend), an explicit [`allreduce`]
//! implementation whose round structure matches an MPI reduce+broadcast
//! tree — including the [`sparse`] Δv/Δṽ message form of §6 — and an
//! alpha-beta [`cost`] model that accounts communication time per round
//! exactly the way the figures split compute vs. "Comm. Time". All
//! algorithmic quantities (rounds, bytes moved, gap-vs-communications)
//! are identical to a real deployment; only wall-clock is modeled, and
//! both modeled and real wall-clock are recorded.

pub mod allreduce;
pub mod cluster;
pub mod cost;
pub mod pool;
pub mod sparse;

pub use cluster::Cluster;
pub use cost::CostModel;
pub use pool::WorkerPool;
pub use sparse::{Delta, SparseDelta};
