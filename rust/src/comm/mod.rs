//! The multi-machine substrate: simulated and real.
//!
//! The paper runs on an OpenMPI cluster with one process per machine
//! (§10: "we use one processor to simulate one machine"). Two in-process
//! backends simulate that — one *worker* per machine executed serially
//! or by a persistent thread [`pool`] ([`cluster`] selects the backend) —
//! and a third runs it for real: the [`tcp`] backend hosts every machine
//! in its own OS process behind the length-prefixed [`wire`] protocol,
//! with actual wire bytes recorded (DESIGN.md §9). All backends share an
//! explicit [`allreduce`] implementation whose round structure matches
//! an MPI reduce+broadcast tree — including the [`sparse`] Δv/Δṽ message
//! form of §6 — and an alpha-beta [`cost`] model that accounts
//! communication time per round exactly the way the figures split
//! compute vs. "Comm. Time". All algorithmic quantities (rounds, bytes
//! moved, gap-vs-communications) are identical across backends — the
//! Tcp-vs-Serial parity tests pin them bit for bit.
//!
//! The total-decoding discipline (DESIGN.md §12) is enforced twice: by
//! `dadm-lint check` and by the module-wide clippy deny below — no
//! `unwrap`/`expect` in non-test communication code (`clippy.toml`
//! exempts tests); the audited exceptions carry an explicit `#[allow]`
//! beside their `dadm-lint: allow` waiver.
#![deny(clippy::unwrap_used, clippy::expect_used)]

pub mod allreduce;
pub mod cluster;
pub mod cost;
pub mod error;
pub mod pool;
pub mod sparse;
pub mod tcp;
pub mod wire;

pub use cluster::{run_subgroup, Cluster};
pub use cost::CostModel;
pub use error::{CommError, CommResult};
pub use pool::WorkerPool;
pub use sparse::{Delta, SparseDelta};
pub use tcp::{FaultTolerance, TcpCluster, TcpClusterBuilder, TcpHandle, WireStats};
