//! Typed communication errors (DESIGN.md §14).
//!
//! Every fallible operation in `comm/` returns [`CommResult`]: a
//! [`CommError`] that callers *match on* — death detection in the
//! fault-tolerant TCP backend dispatches on [`CommError::Disconnect`] /
//! [`CommError::Timeout`] variants, never on rendered message strings
//! (the pre-PR-8 `is_disconnect` hack). The `worker` slot carries the
//! machine index once the failing connection is known; errors raised
//! below that attribution point (inside [`super::wire`], inside a single
//! socket read) travel with `None` and are tagged by the first caller
//! that knows which machine it was talking to ([`CommError::for_worker`]).
//!
//! [`CommError`] implements [`std::error::Error`], so non-`comm` callers
//! (`cli`, examples, tests) keep using `?` into `anyhow::Result` through
//! the std-error blanket — the typed boundary is `comm/`-internal and
//! costs the rest of the crate nothing.

use std::fmt;
use std::io;

use super::wire::WireError;

/// `Result` alias every `comm/` operation uses.
pub type CommResult<T> = Result<T, CommError>;

/// A communication failure, classified for programmatic dispatch.
#[derive(Debug)]
pub enum CommError {
    /// The peer hung up: clean EOF, connection reset, or broken pipe.
    /// A killed worker process surfaces here (the OS closes its sockets
    /// immediately), so death detection is usually instant.
    Disconnect {
        /// Machine index of the dead connection, once attributed.
        worker: Option<u32>,
    },
    /// No frame arrived within the configured `--worker-timeout`: the
    /// peer process is alive enough to keep the socket open but wedged
    /// (or the network is partitioned).
    Timeout {
        /// Machine index of the silent connection, once attributed.
        worker: Option<u32>,
    },
    /// The wire codec rejected a frame (malformed payload, unknown tag,
    /// oversized length) or could not represent one (encode-side caps).
    Decode(WireError),
    /// Handshake version disagreement — the peer speaks a different
    /// protocol revision.
    VersionSkew {
        /// The version the peer announced.
        theirs: u16,
        /// The version this side speaks ([`super::wire::WIRE_VERSION`]).
        ours: u16,
    },
    /// The worker itself reported a failure (a [`super::wire::Frame::Error`]
    /// frame): the transport is healthy, the remote computation is not.
    WorkerFault {
        /// Machine index of the faulting worker.
        id: u32,
        /// The worker's rendered failure message, verbatim.
        message: String,
    },
    /// Any other I/O failure on the socket.
    Io {
        /// Machine index of the failing connection, once attributed.
        worker: Option<u32>,
        /// The underlying OS error.
        source: io::Error,
    },
}

impl CommError {
    /// Attribute this error to machine `id` (fills the `worker` slot on
    /// the connection-level variants; fault/decode/skew variants already
    /// carry their own context and pass through unchanged).
    #[must_use]
    pub fn for_worker(self, id: u32) -> Self {
        match self {
            CommError::Disconnect { worker: None } => CommError::Disconnect { worker: Some(id) },
            CommError::Timeout { worker: None } => CommError::Timeout { worker: Some(id) },
            CommError::Io {
                worker: None,
                source,
            } => CommError::Io {
                worker: Some(id),
                source,
            },
            other => other,
        }
    }

    /// The machine index this error is attributed to, if known.
    pub fn worker(&self) -> Option<u32> {
        match self {
            CommError::Disconnect { worker }
            | CommError::Timeout { worker }
            | CommError::Io { worker, .. } => *worker,
            CommError::WorkerFault { id, .. } => Some(*id),
            CommError::Decode(_) | CommError::VersionSkew { .. } => None,
        }
    }

    /// Whether this failure means the *connection* is dead or silent —
    /// the condition that triggers resurrection (a [`CommError::WorkerFault`]
    /// is a healthy transport reporting a computation error; replaying
    /// the same work would fault identically, so it is not recoverable).
    pub fn is_connection_death(&self) -> bool {
        matches!(
            self,
            CommError::Disconnect { .. } | CommError::Timeout { .. }
        )
    }
}

fn fmt_worker(worker: &Option<u32>) -> String {
    match worker {
        Some(id) => format!("worker {id}"),
        None => "peer".to_string(),
    }
}

impl fmt::Display for CommError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CommError::Disconnect { worker } => {
                write!(f, "{} disconnected", fmt_worker(worker))
            }
            CommError::Timeout { worker } => {
                write!(f, "{} timed out (no frame within the liveness deadline)", fmt_worker(worker))
            }
            CommError::Decode(e) => write!(f, "wire codec error: {e}"),
            CommError::VersionSkew { theirs, ours } => write!(
                f,
                "protocol version mismatch: peer speaks v{theirs}, this side v{ours}"
            ),
            CommError::WorkerFault { id, message } => {
                write!(f, "worker {id} fault: {message}")
            }
            CommError::Io { worker, source } => {
                write!(f, "i/o error on {}: {source}", fmt_worker(worker))
            }
        }
    }
}

impl std::error::Error for CommError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            CommError::Decode(e) => Some(e),
            CommError::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

/// Classify an OS error: hangup kinds become [`CommError::Disconnect`],
/// deadline kinds [`CommError::Timeout`], the rest [`CommError::Io`] —
/// all unattributed until a caller knows the machine index.
impl From<io::Error> for CommError {
    fn from(e: io::Error) -> Self {
        match e.kind() {
            io::ErrorKind::UnexpectedEof
            | io::ErrorKind::ConnectionReset
            | io::ErrorKind::ConnectionAborted
            | io::ErrorKind::BrokenPipe => CommError::Disconnect { worker: None },
            io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut => {
                CommError::Timeout { worker: None }
            }
            _ => CommError::Io {
                worker: None,
                source: e,
            },
        }
    }
}

impl From<WireError> for CommError {
    fn from(e: WireError) -> Self {
        match e {
            WireError::VersionSkew { got, want } => CommError::VersionSkew {
                theirs: got,
                ours: want,
            },
            other => CommError::Decode(other),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn io_kinds_classify_into_variants() {
        for kind in [
            io::ErrorKind::UnexpectedEof,
            io::ErrorKind::ConnectionReset,
            io::ErrorKind::ConnectionAborted,
            io::ErrorKind::BrokenPipe,
        ] {
            let e = CommError::from(io::Error::new(kind, "x"));
            assert!(
                matches!(e, CommError::Disconnect { worker: None }),
                "{kind:?} must classify as Disconnect, got {e:?}"
            );
        }
        for kind in [io::ErrorKind::WouldBlock, io::ErrorKind::TimedOut] {
            let e = CommError::from(io::Error::new(kind, "x"));
            assert!(
                matches!(e, CommError::Timeout { worker: None }),
                "{kind:?} must classify as Timeout, got {e:?}"
            );
        }
        let e = CommError::from(io::Error::new(io::ErrorKind::PermissionDenied, "x"));
        assert!(matches!(e, CommError::Io { worker: None, .. }));
    }

    #[test]
    fn for_worker_attributes_connection_variants_only() {
        let e = CommError::Disconnect { worker: None }.for_worker(3);
        assert_eq!(e.worker(), Some(3));
        let e = CommError::Timeout { worker: None }.for_worker(1);
        assert_eq!(e.worker(), Some(1));
        // Already-attributed errors keep their first attribution.
        let e = CommError::Disconnect { worker: Some(2) }.for_worker(9);
        assert_eq!(e.worker(), Some(2));
        // Fault/skew variants pass through unchanged.
        let e = CommError::VersionSkew { theirs: 3, ours: 5 }.for_worker(0);
        assert_eq!(e.worker(), None);
    }

    #[test]
    fn version_skew_maps_from_wire_error() {
        let e = CommError::from(WireError::VersionSkew { got: 4, want: 5 });
        match e {
            CommError::VersionSkew { theirs, ours } => {
                assert_eq!((theirs, ours), (4, 5));
            }
            other => panic!("expected VersionSkew, got {other:?}"),
        }
        let e = CommError::from(WireError::Malformed("bad".into()));
        assert!(matches!(e, CommError::Decode(_)));
    }

    #[test]
    fn display_names_the_worker_and_keeps_fault_messages() {
        let e = CommError::WorkerFault {
            id: 2,
            message: "no partition assigned".into(),
        };
        let s = format!("{e}");
        assert!(s.contains("worker 2"), "{s}");
        assert!(s.contains("no partition assigned"), "{s}");

        let e = CommError::Disconnect { worker: Some(1) };
        assert!(format!("{e}").contains("worker 1"));

        let e = CommError::VersionSkew { theirs: 4, ours: 5 };
        let s = format!("{e}");
        assert!(s.contains("version"), "{s}");
        assert!(s.contains("v4") && s.contains("v5"), "{s}");
    }

    #[test]
    fn connection_death_is_disconnect_or_timeout() {
        assert!(CommError::Disconnect { worker: None }.is_connection_death());
        assert!(CommError::Timeout { worker: Some(0) }.is_connection_death());
        assert!(!CommError::Decode(WireError::FrameTooLarge { len: 1 }).is_connection_death());
        assert!(!CommError::WorkerFault {
            id: 0,
            message: String::new()
        }
        .is_connection_death());
    }
}
