//! Sparse Δv/Δṽ messages — the real-data-path form of the paper's §6
//! remark that "it may be beneficial to pass Δṽ instead, especially when
//! Δṽ is sparse but ṽ is dense" (see DESIGN.md §7).
//!
//! A mini-batch local step touches only the coordinates covered by the
//! sampled rows, so on rcv1-style data the per-round `Δv_ℓ` has support
//! `≪ d`. Workers therefore emit a [`Delta`]: either an index/value
//! [`SparseDelta`] message (12 B per stored entry on the wire: `u32`
//! index + `f64` value) or a dense vector when the support is wide enough
//! that the sparse encoding would be *larger*. The tree aggregation
//! ([`tree_allreduce_delta`]) merges sparse messages by index with the
//! same binary-tree round structure as the dense
//! [`super::allreduce::tree_allreduce`] — identical pairwise addition
//! order, so the floating-point result matches the dense reduction
//! exactly up to `0.0 + x` no-ops — and falls back to dense mid-tree as
//! soon as a merged message crosses the density threshold.

/// A sparse delta message: coordinate indices (strictly increasing) with
/// their values, plus the full dimension `d` it is a delta over.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SparseDelta {
    /// Full vector dimension `d`.
    pub dim: usize,
    /// Touched coordinates, strictly increasing.
    pub idx: Vec<u32>,
    /// Values, `val[k]` at coordinate `idx[k]`.
    pub val: Vec<f64>,
}

impl SparseDelta {
    /// Build from a dense vector, keeping only the non-zero entries.
    pub fn from_dense(dense: &[f64]) -> Self {
        let mut idx = Vec::new();
        let mut val = Vec::new();
        for (j, &v) in dense.iter().enumerate() {
            if v != 0.0 {
                idx.push(j as u32);
                val.push(v);
            }
        }
        SparseDelta {
            dim: dense.len(),
            idx,
            val,
        }
    }

    /// Stored entries (the message size in index/value pairs).
    pub fn nnz(&self) -> usize {
        self.idx.len()
    }

    /// `out[idx[k]] += val[k]` for every stored entry.
    pub fn add_into(&self, out: &mut [f64]) {
        debug_assert_eq!(out.len(), self.dim);
        for (&j, &v) in self.idx.iter().zip(&self.val) {
            out[j as usize] += v;
        }
    }

    /// Materialize as a dense vector of length `dim`.
    pub fn to_dense(&self) -> Vec<f64> {
        let mut out = vec![0.0; self.dim];
        self.add_into(&mut out);
        out
    }
}

/// Wire bytes of one stored sparse entry: `u32` index + `f64` value.
/// The single source of truth for the sparse/dense break-even — used by
/// the reduce densify rule ([`should_densify`]), the cost-model message
/// sizing ([`sparse_message_elems`]), and the TCP wire layer's actual
/// encoding ([`super::wire`]), which all must agree.
pub const SPARSE_ENTRY_BYTES: usize = 12;

/// Wire bytes of one dense `f64` element.
pub const DENSE_ENTRY_BYTES: usize = 8;

/// Whether a sparse message of `nnz` stored entries over dimension `dim`
/// should be sent (and reduced) densely instead: a stored entry costs
/// [`SPARSE_ENTRY_BYTES`] against [`DENSE_ENTRY_BYTES`] per dense
/// element (1.5 dense-equivalent elements each), so the sparse form
/// stops paying for itself at `nnz ≥ ⅔·d`.
pub fn should_densify(nnz: usize, dim: usize) -> bool {
    nnz * SPARSE_ENTRY_BYTES >= dim * DENSE_ENTRY_BYTES
}

/// Wire size of a sparse message of `nnz` entries over dimension `dim`,
/// in dense-equivalent f64 elements:
/// `⌈nnz · SPARSE_ENTRY_BYTES / DENSE_ENTRY_BYTES⌉` (= `⌈1.5·nnz⌉`),
/// capped at the dense size `dim`.
pub fn sparse_message_elems(nnz: usize, dim: usize) -> usize {
    ((nnz * SPARSE_ENTRY_BYTES).div_ceil(DENSE_ENTRY_BYTES)).min(dim)
}

/// A per-round delta message: dense vector or sparse index/value pairs.
#[derive(Clone, Debug, PartialEq)]
pub enum Delta {
    /// Dense length-`d` message.
    Dense(Vec<f64>),
    /// Sparse message (small support).
    Sparse(SparseDelta),
}

impl Delta {
    /// Full vector dimension `d`.
    pub fn dim(&self) -> usize {
        match self {
            Delta::Dense(v) => v.len(),
            Delta::Sparse(s) => s.dim,
        }
    }

    /// Stored entries actually carried by the message.
    pub fn nnz(&self) -> usize {
        match self {
            Delta::Dense(v) => v.len(),
            Delta::Sparse(s) => s.nnz(),
        }
    }

    /// Wire size of this message in dense-equivalent f64 elements: the
    /// quantity the α-β cost model charges. A dense message is `d`
    /// elements; a sparse one is `⌈1.5·nnz⌉` (u32 index + f64 value per
    /// entry), capped at the dense size.
    pub fn message_elems(&self) -> usize {
        match self {
            Delta::Dense(v) => v.len(),
            Delta::Sparse(s) => sparse_message_elems(s.nnz(), s.dim),
        }
    }

    /// Scale every stored value by `c`.
    pub fn scale(&mut self, c: f64) {
        match self {
            Delta::Dense(v) => {
                for x in v.iter_mut() {
                    *x *= c;
                }
            }
            Delta::Sparse(s) => {
                for x in s.val.iter_mut() {
                    *x *= c;
                }
            }
        }
    }

    /// `out += self` (dense accumulate).
    pub fn add_into(&self, out: &mut [f64]) {
        match self {
            Delta::Dense(v) => {
                debug_assert_eq!(out.len(), v.len());
                for (o, &x) in out.iter_mut().zip(v) {
                    *o += x;
                }
            }
            Delta::Sparse(s) => s.add_into(out),
        }
    }

    /// Materialize as a dense vector of length `dim`.
    pub fn into_dense(self) -> Vec<f64> {
        match self {
            Delta::Dense(v) => v,
            Delta::Sparse(s) => s.to_dense(),
        }
    }
}

/// Merge two scaled contributions (one tree edge). Sparse–sparse merges
/// walk both sorted index lists; the result densifies as soon as its
/// support crosses [`should_densify`], so wide merges near the tree root
/// degrade to plain dense adds instead of ever-longer index walks.
fn merge(a: Delta, b: Delta) -> Delta {
    match (a, b) {
        (Delta::Dense(mut x), Delta::Dense(y)) => {
            debug_assert_eq!(x.len(), y.len());
            for (p, &q) in x.iter_mut().zip(&y) {
                *p += q;
            }
            Delta::Dense(x)
        }
        (Delta::Dense(mut x), Delta::Sparse(s)) | (Delta::Sparse(s), Delta::Dense(mut x)) => {
            // f64 addition is commutative, so folding the sparse side into
            // the dense buffer matches the left+right order either way.
            s.add_into(&mut x);
            Delta::Dense(x)
        }
        (Delta::Sparse(a), Delta::Sparse(b)) => {
            debug_assert_eq!(a.dim, b.dim);
            let mut idx = Vec::with_capacity(a.nnz() + b.nnz());
            let mut val = Vec::with_capacity(a.nnz() + b.nnz());
            let (mut i, mut k) = (0usize, 0usize);
            while i < a.idx.len() && k < b.idx.len() {
                match a.idx[i].cmp(&b.idx[k]) {
                    std::cmp::Ordering::Less => {
                        idx.push(a.idx[i]);
                        val.push(a.val[i]);
                        i += 1;
                    }
                    std::cmp::Ordering::Greater => {
                        idx.push(b.idx[k]);
                        val.push(b.val[k]);
                        k += 1;
                    }
                    std::cmp::Ordering::Equal => {
                        idx.push(a.idx[i]);
                        val.push(a.val[i] + b.val[k]);
                        i += 1;
                        k += 1;
                    }
                }
            }
            idx.extend_from_slice(&a.idx[i..]);
            val.extend_from_slice(&a.val[i..]);
            idx.extend_from_slice(&b.idx[k..]);
            val.extend_from_slice(&b.val[k..]);
            let merged = SparseDelta {
                dim: a.dim,
                idx,
                val,
            };
            if should_densify(merged.nnz(), merged.dim) {
                Delta::Dense(merged.to_dense())
            } else {
                Delta::Sparse(merged)
            }
        }
    }
}

/// Sparse-aware weighted tree-reduce: `Σ_ℓ weight_ℓ · contributions_ℓ`
/// over [`Delta`] messages, with the same pairwise binary-tree round
/// structure as [`super::allreduce::tree_allreduce`]. Consumes the
/// per-worker messages (they are exactly what would go on the wire).
///
/// Returns the reduced total plus the largest message (in
/// dense-equivalent elements, [`Delta::message_elems`]) observed
/// anywhere in the tree — leaves *and* merged inner messages, whose
/// support grows toward the root — which is what the cost model should
/// charge as the reduce leg's per-hop transfer size.
pub fn tree_allreduce_delta(mut contributions: Vec<Delta>, weights: &[f64]) -> (Delta, usize) {
    assert_eq!(contributions.len(), weights.len());
    assert!(!contributions.is_empty());
    let d = contributions[0].dim();
    for (c, &w) in contributions.iter_mut().zip(weights) {
        assert_eq!(c.dim(), d, "ragged contribution");
        c.scale(w);
    }
    let mut max_elems = contributions
        .iter()
        .map(Delta::message_elems)
        .max()
        .unwrap_or(0);
    let mut stride = 1usize;
    while stride < contributions.len() {
        let mut i = 0;
        while i + stride < contributions.len() {
            // The right operand is dead after this edge (the next tree
            // level only visits multiples of 2·stride), so take both out,
            // merge, and put the result back at `i`.
            let right = std::mem::replace(
                &mut contributions[i + stride],
                Delta::Sparse(SparseDelta::default()),
            );
            let left = std::mem::replace(
                &mut contributions[i],
                Delta::Sparse(SparseDelta::default()),
            );
            let merged = merge(left, right);
            max_elems = max_elems.max(merged.message_elems());
            contributions[i] = merged;
            i += stride * 2;
        }
        stride *= 2;
    }
    (contributions.swap_remove(0), max_elems)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::allreduce::tree_allreduce;
    use crate::testing::prop::for_each_case;

    #[test]
    fn sparse_roundtrip() {
        let dense = vec![0.0, 1.5, 0.0, -2.0, 0.0];
        let s = SparseDelta::from_dense(&dense);
        assert_eq!(s.idx, vec![1, 3]);
        assert_eq!(s.val, vec![1.5, -2.0]);
        assert_eq!(s.to_dense(), dense);
        assert_eq!(s.nnz(), 2);
    }

    #[test]
    fn message_elems_caps_at_dense() {
        // 2 entries over d=8: ⌈3⌉ = 3 elems < 8.
        let sparse = Delta::Sparse(SparseDelta {
            dim: 8,
            idx: vec![0, 5],
            val: vec![1.0, 2.0],
        });
        assert_eq!(sparse.message_elems(), 3);
        // 7 entries over d=8: ⌈10.5⌉ = 11, capped at 8.
        let wide = Delta::Sparse(SparseDelta {
            dim: 8,
            idx: (0..7).collect(),
            val: vec![1.0; 7],
        });
        assert_eq!(wide.message_elems(), 8);
        assert_eq!(Delta::Dense(vec![0.0; 8]).message_elems(), 8);
    }

    #[test]
    fn densify_threshold_tracks_wire_breakeven() {
        assert!(!should_densify(0, 9));
        assert!(!should_densify(5, 9)); // 7.5 elems < 9
        assert!(should_densify(6, 9)); // 9 elems == 9
        assert!(should_densify(9, 9));
    }

    #[test]
    fn densify_and_message_size_share_one_breakeven() {
        // The densify rule and the cost-model message size must agree at
        // every (nnz, dim): a message densifies exactly when its sparse
        // encoding would be at least the dense one — both derived from
        // the same SPARSE_ENTRY_BYTES / DENSE_ENTRY_BYTES constants.
        assert_eq!(SPARSE_ENTRY_BYTES, 12);
        assert_eq!(DENSE_ENTRY_BYTES, 8);
        for dim in 1..40usize {
            for nnz in 0..=dim {
                let sparse_bytes = nnz * SPARSE_ENTRY_BYTES;
                let dense_bytes = dim * DENSE_ENTRY_BYTES;
                assert_eq!(should_densify(nnz, dim), sparse_bytes >= dense_bytes);
                if !should_densify(nnz, dim) {
                    assert!(sparse_message_elems(nnz, dim) <= dim);
                    assert_eq!(
                        sparse_message_elems(nnz, dim),
                        sparse_bytes.div_ceil(DENSE_ENTRY_BYTES)
                    );
                }
            }
        }
    }

    #[test]
    fn sparse_sparse_merge_by_index() {
        let a = Delta::Sparse(SparseDelta {
            dim: 100,
            idx: vec![1, 4, 7],
            val: vec![1.0, 2.0, 3.0],
        });
        let b = Delta::Sparse(SparseDelta {
            dim: 100,
            idx: vec![4, 9],
            val: vec![10.0, 20.0],
        });
        match merge(a, b) {
            Delta::Sparse(s) => {
                assert_eq!(s.idx, vec![1, 4, 7, 9]);
                assert_eq!(s.val, vec![1.0, 12.0, 3.0, 20.0]);
            }
            Delta::Dense(_) => panic!("small merge must stay sparse"),
        }
    }

    #[test]
    fn wide_merge_densifies() {
        let a = Delta::Sparse(SparseDelta {
            dim: 6,
            idx: vec![0, 2, 4],
            val: vec![1.0; 3],
        });
        let b = Delta::Sparse(SparseDelta {
            dim: 6,
            idx: vec![1, 3],
            val: vec![1.0; 2],
        });
        // merged nnz = 5, 5·3 ≥ 6·2 ⇒ dense.
        match merge(a, b) {
            Delta::Dense(v) => assert_eq!(v, vec![1.0, 1.0, 1.0, 1.0, 1.0, 0.0]),
            Delta::Sparse(_) => panic!("wide merge must densify"),
        }
    }

    #[test]
    fn single_contribution_scaled() {
        let (got, max_elems) = tree_allreduce_delta(
            vec![Delta::Sparse(SparseDelta {
                dim: 3,
                idx: vec![2],
                val: vec![2.0],
            })],
            &[0.5],
        );
        assert_eq!(got.into_dense(), vec![0.0, 0.0, 1.0]);
        assert_eq!(max_elems, 2); // ⌈1.5·1⌉
    }

    #[test]
    fn max_message_tracks_merged_growth() {
        // Four disjoint 2-entry messages over d=1000: leaves are 3 elems,
        // but the root merge carries 8 entries = 12 elems — the cost
        // model must see the tree's largest message, not the leaf size.
        let contribs: Vec<Delta> = (0..4)
            .map(|l| {
                Delta::Sparse(SparseDelta {
                    dim: 1000,
                    idx: vec![(l * 2) as u32, (l * 2 + 1) as u32],
                    val: vec![1.0, 1.0],
                })
            })
            .collect();
        let (total, max_elems) = tree_allreduce_delta(contribs, &[1.0; 4]);
        assert_eq!(total.nnz(), 8);
        assert_eq!(max_elems, 12);
    }

    #[test]
    fn prop_matches_dense_tree_reduce() {
        // Random mixes of dense and sparse messages across random machine
        // counts and densities must match the dense tree reduction within
        // fp tolerance.
        for_each_case(0x5DE17A, 60, |g| {
            let m = g.usize_in(1, 16);
            let d = g.usize_in(1, 40);
            let density = g.f64_in(0.0, 1.0);
            let dense: Vec<Vec<f64>> = (0..m)
                .map(|_| {
                    (0..d)
                        .map(|_| {
                            if g.bool(density) {
                                g.f64_in(-5.0, 5.0)
                            } else {
                                0.0
                            }
                        })
                        .collect()
                })
                .collect();
            let weights = g.vec_f64(m, 0.0, 1.0);
            let want = tree_allreduce(&dense, &weights);
            let deltas: Vec<Delta> = dense
                .iter()
                .map(|v| {
                    if g.bool(0.5) {
                        Delta::Dense(v.clone())
                    } else {
                        Delta::Sparse(SparseDelta::from_dense(v))
                    }
                })
                .collect();
            let got = tree_allreduce_delta(deltas, &weights).0.into_dense();
            for j in 0..d {
                assert!(
                    (got[j] - want[j]).abs() < 1e-9,
                    "sparse tree {} vs dense tree {} at {j}",
                    got[j],
                    want[j]
                );
            }
        });
    }
}
