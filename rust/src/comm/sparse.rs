//! Sparse Δv/Δṽ messages — the real-data-path form of the paper's §6
//! remark that "it may be beneficial to pass Δṽ instead, especially when
//! Δṽ is sparse but ṽ is dense" (see DESIGN.md §7).
//!
//! A mini-batch local step touches only the coordinates covered by the
//! sampled rows, so on rcv1-style data the per-round `Δv_ℓ` has support
//! `≪ d`. Workers therefore emit a [`Delta`]: either an index/value
//! [`SparseDelta`] message (12 B per stored entry on the wire: `u32`
//! index + `f64` value) or a dense vector when the support is wide enough
//! that the sparse encoding would be *larger*. The tree aggregation
//! ([`tree_allreduce_delta`]) merges sparse messages by index with the
//! same binary-tree round structure as the dense
//! [`super::allreduce::tree_allreduce`] — identical pairwise addition
//! order, so the floating-point result matches the dense reduction
//! exactly up to `0.0 + x` no-ops — and falls back to dense mid-tree as
//! soon as a merged message crosses the density threshold.

/// A sparse delta message: coordinate indices (strictly increasing) with
/// their values, plus the full dimension `d` it is a delta over.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SparseDelta {
    /// Full vector dimension `d`.
    pub dim: usize,
    /// Touched coordinates, strictly increasing.
    pub idx: Vec<u32>,
    /// Values, `val[k]` at coordinate `idx[k]`.
    pub val: Vec<f64>,
}

impl SparseDelta {
    /// Build from a dense vector, keeping only the non-zero entries.
    pub fn from_dense(dense: &[f64]) -> Self {
        let mut idx = Vec::new();
        let mut val = Vec::new();
        for (j, &v) in dense.iter().enumerate() {
            if v != 0.0 {
                idx.push(j as u32);
                val.push(v);
            }
        }
        SparseDelta {
            dim: dense.len(),
            idx,
            val,
        }
    }

    /// Stored entries (the message size in index/value pairs).
    pub fn nnz(&self) -> usize {
        self.idx.len()
    }

    /// `out[idx[k]] += val[k]` for every stored entry.
    pub fn add_into(&self, out: &mut [f64]) {
        debug_assert_eq!(out.len(), self.dim);
        for (&j, &v) in self.idx.iter().zip(&self.val) {
            out[j as usize] += v;
        }
    }

    /// Materialize as a dense vector of length `dim`.
    pub fn to_dense(&self) -> Vec<f64> {
        let mut out = vec![0.0; self.dim];
        self.add_into(&mut out);
        out
    }
}

/// Wire bytes of one stored sparse entry: `u32` index + `f64` value.
/// The single source of truth for the sparse/dense break-even — used by
/// the reduce densify rule ([`should_densify`]), the cost-model message
/// sizing ([`sparse_message_elems`]), and the TCP wire layer's actual
/// encoding ([`super::wire`]), which all must agree.
pub const SPARSE_ENTRY_BYTES: usize = 12;

/// Wire bytes of one dense `f64` element.
pub const DENSE_ENTRY_BYTES: usize = 8;

/// Value encoding for delta payloads (DESIGN.md §13). `F64` is the
/// exact default — bit-identical to the uncompressed pipeline, no
/// residual kept. The lossy codecs quantize every stored value at the
/// sender and carry the quantization error forward as an error-feedback
/// residual ([`compress_delta`]), so the long-run sum of transmitted
/// images tracks the exact sum to within one quantization step.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum DeltaCodec {
    /// Exact 8-byte IEEE-754 doubles (the parity-pinned default).
    #[default]
    F64,
    /// 4-byte IEEE-754 singles: widening back to f64 is exact, so the
    /// receiver reconstructs the sender's image bit for bit.
    F32,
    /// 2-byte integer levels against a shared power-of-two step
    /// ([`i16_step`]): scaling by the step never rounds, so quantize,
    /// dequantize, and wire re-encoding are all exact in f64.
    I16,
}

impl DeltaCodec {
    /// Wire bytes of one stored value under this codec.
    pub const fn value_bytes(self) -> usize {
        match self {
            DeltaCodec::F64 => 8,
            DeltaCodec::F32 => 4,
            DeltaCodec::I16 => 2,
        }
    }

    /// Wire bytes of one stored sparse entry: `u32` index + value.
    pub const fn sparse_entry_bytes(self) -> usize {
        4 + self.value_bytes()
    }

    /// Wire bytes of one dense element.
    pub const fn dense_entry_bytes(self) -> usize {
        self.value_bytes()
    }

    /// The config/CLI name (`f64`, `f32`, `i16`).
    pub fn name(self) -> &'static str {
        match self {
            DeltaCodec::F64 => "f64",
            DeltaCodec::F32 => "f32",
            DeltaCodec::I16 => "i16",
        }
    }

    /// Inverse of [`DeltaCodec::name`].
    pub fn parse(s: &str) -> Option<DeltaCodec> {
        match s {
            "f64" => Some(DeltaCodec::F64),
            "f32" => Some(DeltaCodec::F32),
            "i16" => Some(DeltaCodec::I16),
            _ => None,
        }
    }
}

/// Whether a sparse message of `nnz` stored entries over dimension `dim`
/// should be sent (and reduced) densely instead: a stored entry costs
/// [`SPARSE_ENTRY_BYTES`] against [`DENSE_ENTRY_BYTES`] per dense
/// element (1.5 dense-equivalent elements each), so the sparse form
/// stops paying for itself at `nnz ≥ ⅔·d`.
pub fn should_densify(nnz: usize, dim: usize) -> bool {
    should_densify_with(DeltaCodec::F64, nnz, dim)
}

/// Per-codec generalization of [`should_densify`]: the break-even moves
/// with the codec's entry widths — `nnz ≥ ⅔·d` for `f64` (12 B vs 8 B),
/// `nnz ≥ ½·d` for `f32` (8 B vs 4 B), `nnz ≥ ⅓·d` for `i16`
/// (6 B vs 2 B) — narrower values make the per-entry index overhead
/// relatively more expensive, so compressed messages densify sooner.
pub fn should_densify_with(codec: DeltaCodec, nnz: usize, dim: usize) -> bool {
    nnz * codec.sparse_entry_bytes() >= dim * codec.dense_entry_bytes()
}

/// Wire size of a sparse message of `nnz` entries over dimension `dim`,
/// in dense-equivalent f64 elements:
/// `⌈nnz · SPARSE_ENTRY_BYTES / DENSE_ENTRY_BYTES⌉` (= `⌈1.5·nnz⌉`),
/// capped at the dense size `dim`.
pub fn sparse_message_elems(nnz: usize, dim: usize) -> usize {
    sparse_message_elems_with(DeltaCodec::F64, nnz, dim)
}

/// Per-codec generalization of [`sparse_message_elems`]: the message
/// size the cost model charges, in 8-byte dense-equivalent elements,
/// capped at this codec's *dense* encoding of the same vector.
pub fn sparse_message_elems_with(codec: DeltaCodec, nnz: usize, dim: usize) -> usize {
    ((nnz * codec.sparse_entry_bytes()).div_ceil(DENSE_ENTRY_BYTES))
        .min((dim * codec.dense_entry_bytes()).div_ceil(DENSE_ENTRY_BYTES))
}

/// Largest i16 level magnitude used by the scaled-i16 codec. Symmetric
/// (±32767) so negation is exact and `i16::MIN` never appears.
const I16_MAX_Q: f64 = 32767.0;

/// Largest magnitude in a value vector (0.0 when empty) — the input to
/// [`i16_step`], shared by the quantizer here and the wire encoder's
/// canonical step re-derivation.
pub fn max_abs(vals: &[f64]) -> f64 {
    vals.iter().fold(0.0f64, |m, v| m.max(v.abs()))
}

/// The canonical quantization step for the scaled-i16 codec: the
/// smallest power of two `s` with `max_abs / s ≤ 32767`. A power-of-two
/// step makes every scaling exact in f64, which gives the codec its two
/// load-bearing properties: `level · s` reconstructs the sender's image
/// bit for bit, and the wire encoder can re-derive `(s, levels)` from
/// the image values alone (the max-magnitude carry always quantizes to
/// a level in `(16383, 32767]`, so the minimal step of the image vector
/// is the minimal step of the carry vector).
pub fn i16_step(max_abs: f64) -> f64 {
    if !max_abs.is_finite() || max_abs <= 0.0 {
        return 1.0;
    }
    let mut step = 1.0f64;
    while max_abs / step > I16_MAX_Q {
        step *= 2.0;
    }
    while step > f64::MIN_POSITIVE && max_abs / (step * 0.5) <= I16_MAX_Q {
        step *= 0.5;
    }
    step
}

/// The scaled-i16 level of one value for a given step (total: non-finite
/// values saturate through `clamp`/`as`, they never panic).
pub fn i16_level(v: f64, step: f64) -> i16 {
    (v / step).round().clamp(-I16_MAX_Q, I16_MAX_Q) as i16
}

/// The codec image of one value: the exact f64 the receiver
/// reconstructs. `step` is this message's [`i16_step`] (ignored by the
/// other codecs).
pub fn codec_image(codec: DeltaCodec, v: f64, step: f64) -> f64 {
    match codec {
        DeltaCodec::F64 => v,
        DeltaCodec::F32 => {
            let x = v as f32;
            if x.is_finite() || !v.is_finite() {
                x as f64
            } else {
                // A finite f64 beyond f32 range saturates instead of
                // poisoning the image (and the residual) with ±∞.
                f32::MAX.copysign(x) as f64
            }
        }
        DeltaCodec::I16 => i16_level(v, step) as f64 * step,
    }
}

/// Quantize a delta message in place under `codec`, carrying the
/// error-feedback residual (DESIGN.md §13).
///
/// `residual` is the sender's dense unsent-error buffer (resized to the
/// message dimension on first use). The previous rounds' error is
/// folded into the message first, the carry is re-extracted at this
/// codec's sparse/dense break-even ([`should_densify_with`]), every
/// stored value is replaced by its codec image, and the new
/// per-coordinate error `carry − image` is left in `residual` for the
/// next round. The fold and the subtraction are exact in f64 (the image
/// is within half a step of the carry, so Sterbenz cancellation
/// applies), which gives the error-feedback invariant: at every round,
/// `Σ transmitted images + residual == Σ exact deltas` bit for bit.
///
/// `F64` is the identity: message and residual are untouched, keeping
/// that path bit-identical to the uncompressed pipeline.
pub fn compress_delta(delta: &mut Delta, codec: DeltaCodec, residual: &mut Vec<f64>) {
    if codec == DeltaCodec::F64 {
        return;
    }
    let dim = delta.dim();
    residual.resize(dim, 0.0);
    // Fold the message into the carried error: `residual` now holds the
    // exact carry (delta + unsent error), supported on the union.
    delta.add_into(residual);
    let nnz = residual.iter().filter(|v| **v != 0.0).count();
    let step = match codec {
        DeltaCodec::I16 => i16_step(max_abs(residual)),
        _ => 1.0,
    };
    if should_densify_with(codec, nnz, dim) {
        let mut img = vec![0.0; dim];
        for (j, r) in residual.iter_mut().enumerate() {
            let image = codec_image(codec, *r, step);
            img[j] = image;
            *r -= image;
        }
        *delta = Delta::Dense(img);
    } else {
        let mut idx = Vec::with_capacity(nnz);
        let mut val = Vec::with_capacity(nnz);
        for (j, r) in residual.iter_mut().enumerate() {
            if *r != 0.0 {
                let image = codec_image(codec, *r, step);
                if image != 0.0 {
                    idx.push(j as u32);
                    val.push(image);
                }
                *r -= image;
            }
        }
        *delta = Delta::Sparse(SparseDelta { dim, idx, val });
    }
}

/// A per-round delta message: dense vector or sparse index/value pairs.
#[derive(Clone, Debug, PartialEq)]
pub enum Delta {
    /// Dense length-`d` message.
    Dense(Vec<f64>),
    /// Sparse message (small support).
    Sparse(SparseDelta),
}

impl Delta {
    /// Full vector dimension `d`.
    pub fn dim(&self) -> usize {
        match self {
            Delta::Dense(v) => v.len(),
            Delta::Sparse(s) => s.dim,
        }
    }

    /// Stored entries actually carried by the message.
    pub fn nnz(&self) -> usize {
        match self {
            Delta::Dense(v) => v.len(),
            Delta::Sparse(s) => s.nnz(),
        }
    }

    /// Wire size of this message in dense-equivalent f64 elements: the
    /// quantity the α-β cost model charges. A dense message is `d`
    /// elements; a sparse one is `⌈1.5·nnz⌉` (u32 index + f64 value per
    /// entry), capped at the dense size.
    pub fn message_elems(&self) -> usize {
        match self {
            Delta::Dense(v) => v.len(),
            Delta::Sparse(s) => sparse_message_elems(s.nnz(), s.dim),
        }
    }

    /// Scale every stored value by `c`.
    pub fn scale(&mut self, c: f64) {
        match self {
            Delta::Dense(v) => {
                for x in v.iter_mut() {
                    *x *= c;
                }
            }
            Delta::Sparse(s) => {
                for x in s.val.iter_mut() {
                    *x *= c;
                }
            }
        }
    }

    /// `out += self` (dense accumulate).
    pub fn add_into(&self, out: &mut [f64]) {
        match self {
            Delta::Dense(v) => {
                debug_assert_eq!(out.len(), v.len());
                for (o, &x) in out.iter_mut().zip(v) {
                    *o += x;
                }
            }
            Delta::Sparse(s) => s.add_into(out),
        }
    }

    /// Materialize as a dense vector of length `dim`.
    pub fn into_dense(self) -> Vec<f64> {
        match self {
            Delta::Dense(v) => v,
            Delta::Sparse(s) => s.to_dense(),
        }
    }
}

/// Merge two scaled contributions (one tree edge). Sparse–sparse merges
/// walk both sorted index lists; the result densifies as soon as its
/// support crosses [`should_densify`], so wide merges near the tree root
/// degrade to plain dense adds instead of ever-longer index walks.
fn merge(a: Delta, b: Delta) -> Delta {
    match (a, b) {
        (Delta::Dense(mut x), Delta::Dense(y)) => {
            debug_assert_eq!(x.len(), y.len());
            for (p, &q) in x.iter_mut().zip(&y) {
                *p += q;
            }
            Delta::Dense(x)
        }
        (Delta::Dense(mut x), Delta::Sparse(s)) | (Delta::Sparse(s), Delta::Dense(mut x)) => {
            // f64 addition is commutative, so folding the sparse side into
            // the dense buffer matches the left+right order either way.
            s.add_into(&mut x);
            Delta::Dense(x)
        }
        (Delta::Sparse(a), Delta::Sparse(b)) => {
            debug_assert_eq!(a.dim, b.dim);
            let mut idx = Vec::with_capacity(a.nnz() + b.nnz());
            let mut val = Vec::with_capacity(a.nnz() + b.nnz());
            let (mut i, mut k) = (0usize, 0usize);
            while i < a.idx.len() && k < b.idx.len() {
                match a.idx[i].cmp(&b.idx[k]) {
                    std::cmp::Ordering::Less => {
                        idx.push(a.idx[i]);
                        val.push(a.val[i]);
                        i += 1;
                    }
                    std::cmp::Ordering::Greater => {
                        idx.push(b.idx[k]);
                        val.push(b.val[k]);
                        k += 1;
                    }
                    std::cmp::Ordering::Equal => {
                        idx.push(a.idx[i]);
                        val.push(a.val[i] + b.val[k]);
                        i += 1;
                        k += 1;
                    }
                }
            }
            idx.extend_from_slice(&a.idx[i..]);
            val.extend_from_slice(&a.val[i..]);
            idx.extend_from_slice(&b.idx[k..]);
            val.extend_from_slice(&b.val[k..]);
            let merged = SparseDelta {
                dim: a.dim,
                idx,
                val,
            };
            if should_densify(merged.nnz(), merged.dim) {
                Delta::Dense(merged.to_dense())
            } else {
                Delta::Sparse(merged)
            }
        }
    }
}

/// Sparse-aware weighted tree-reduce: `Σ_ℓ weight_ℓ · contributions_ℓ`
/// over [`Delta`] messages, with the same pairwise binary-tree round
/// structure as [`super::allreduce::tree_allreduce`]. Consumes the
/// per-worker messages (they are exactly what would go on the wire).
///
/// Returns the reduced total plus the largest message (in
/// dense-equivalent elements, [`Delta::message_elems`]) observed
/// anywhere in the tree — leaves *and* merged inner messages, whose
/// support grows toward the root — which is what the cost model should
/// charge as the reduce leg's per-hop transfer size.
pub fn tree_allreduce_delta(mut contributions: Vec<Delta>, weights: &[f64]) -> (Delta, usize) {
    assert_eq!(contributions.len(), weights.len());
    assert!(!contributions.is_empty());
    let d = contributions[0].dim();
    for (c, &w) in contributions.iter_mut().zip(weights) {
        assert_eq!(c.dim(), d, "ragged contribution");
        c.scale(w);
    }
    let mut max_elems = contributions
        .iter()
        .map(Delta::message_elems)
        .max()
        .unwrap_or(0);
    let mut stride = 1usize;
    while stride < contributions.len() {
        let mut i = 0;
        while i + stride < contributions.len() {
            // The right operand is dead after this edge (the next tree
            // level only visits multiples of 2·stride), so take both out,
            // merge, and put the result back at `i`.
            let right = std::mem::replace(
                &mut contributions[i + stride],
                Delta::Sparse(SparseDelta::default()),
            );
            let left = std::mem::replace(
                &mut contributions[i],
                Delta::Sparse(SparseDelta::default()),
            );
            let merged = merge(left, right);
            max_elems = max_elems.max(merged.message_elems());
            contributions[i] = merged;
            i += stride * 2;
        }
        stride *= 2;
    }
    (contributions.swap_remove(0), max_elems)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::allreduce::tree_allreduce;
    use crate::testing::prop::for_each_case;

    #[test]
    fn sparse_roundtrip() {
        let dense = vec![0.0, 1.5, 0.0, -2.0, 0.0];
        let s = SparseDelta::from_dense(&dense);
        assert_eq!(s.idx, vec![1, 3]);
        assert_eq!(s.val, vec![1.5, -2.0]);
        assert_eq!(s.to_dense(), dense);
        assert_eq!(s.nnz(), 2);
    }

    #[test]
    fn message_elems_caps_at_dense() {
        // 2 entries over d=8: ⌈3⌉ = 3 elems < 8.
        let sparse = Delta::Sparse(SparseDelta {
            dim: 8,
            idx: vec![0, 5],
            val: vec![1.0, 2.0],
        });
        assert_eq!(sparse.message_elems(), 3);
        // 7 entries over d=8: ⌈10.5⌉ = 11, capped at 8.
        let wide = Delta::Sparse(SparseDelta {
            dim: 8,
            idx: (0..7).collect(),
            val: vec![1.0; 7],
        });
        assert_eq!(wide.message_elems(), 8);
        assert_eq!(Delta::Dense(vec![0.0; 8]).message_elems(), 8);
    }

    #[test]
    fn densify_threshold_tracks_wire_breakeven() {
        assert!(!should_densify(0, 9));
        assert!(!should_densify(5, 9)); // 7.5 elems < 9
        assert!(should_densify(6, 9)); // 9 elems == 9
        assert!(should_densify(9, 9));
    }

    #[test]
    fn densify_and_message_size_share_one_breakeven() {
        // The densify rule and the cost-model message size must agree at
        // every (nnz, dim): a message densifies exactly when its sparse
        // encoding would be at least the dense one — both derived from
        // the same SPARSE_ENTRY_BYTES / DENSE_ENTRY_BYTES constants.
        assert_eq!(SPARSE_ENTRY_BYTES, 12);
        assert_eq!(DENSE_ENTRY_BYTES, 8);
        for dim in 1..40usize {
            for nnz in 0..=dim {
                let sparse_bytes = nnz * SPARSE_ENTRY_BYTES;
                let dense_bytes = dim * DENSE_ENTRY_BYTES;
                assert_eq!(should_densify(nnz, dim), sparse_bytes >= dense_bytes);
                if !should_densify(nnz, dim) {
                    assert!(sparse_message_elems(nnz, dim) <= dim);
                    assert_eq!(
                        sparse_message_elems(nnz, dim),
                        sparse_bytes.div_ceil(DENSE_ENTRY_BYTES)
                    );
                }
            }
        }
    }

    #[test]
    fn sparse_sparse_merge_by_index() {
        let a = Delta::Sparse(SparseDelta {
            dim: 100,
            idx: vec![1, 4, 7],
            val: vec![1.0, 2.0, 3.0],
        });
        let b = Delta::Sparse(SparseDelta {
            dim: 100,
            idx: vec![4, 9],
            val: vec![10.0, 20.0],
        });
        match merge(a, b) {
            Delta::Sparse(s) => {
                assert_eq!(s.idx, vec![1, 4, 7, 9]);
                assert_eq!(s.val, vec![1.0, 12.0, 3.0, 20.0]);
            }
            Delta::Dense(_) => panic!("small merge must stay sparse"),
        }
    }

    #[test]
    fn wide_merge_densifies() {
        let a = Delta::Sparse(SparseDelta {
            dim: 6,
            idx: vec![0, 2, 4],
            val: vec![1.0; 3],
        });
        let b = Delta::Sparse(SparseDelta {
            dim: 6,
            idx: vec![1, 3],
            val: vec![1.0; 2],
        });
        // merged nnz = 5, 5·3 ≥ 6·2 ⇒ dense.
        match merge(a, b) {
            Delta::Dense(v) => assert_eq!(v, vec![1.0, 1.0, 1.0, 1.0, 1.0, 0.0]),
            Delta::Sparse(_) => panic!("wide merge must densify"),
        }
    }

    #[test]
    fn single_contribution_scaled() {
        let (got, max_elems) = tree_allreduce_delta(
            vec![Delta::Sparse(SparseDelta {
                dim: 3,
                idx: vec![2],
                val: vec![2.0],
            })],
            &[0.5],
        );
        assert_eq!(got.into_dense(), vec![0.0, 0.0, 1.0]);
        assert_eq!(max_elems, 2); // ⌈1.5·1⌉
    }

    #[test]
    fn max_message_tracks_merged_growth() {
        // Four disjoint 2-entry messages over d=1000: leaves are 3 elems,
        // but the root merge carries 8 entries = 12 elems — the cost
        // model must see the tree's largest message, not the leaf size.
        let contribs: Vec<Delta> = (0..4)
            .map(|l| {
                Delta::Sparse(SparseDelta {
                    dim: 1000,
                    idx: vec![(l * 2) as u32, (l * 2 + 1) as u32],
                    val: vec![1.0, 1.0],
                })
            })
            .collect();
        let (total, max_elems) = tree_allreduce_delta(contribs, &[1.0; 4]);
        assert_eq!(total.nnz(), 8);
        assert_eq!(max_elems, 12);
    }

    #[test]
    fn merge_preserves_reserved_capacity() {
        // The sparse–sparse merge pre-reserves both output buffers to the
        // summed-nnz upper bound, so the two-pointer walk never
        // reallocates. Disjoint supports make the merged length hit the
        // bound exactly; an unreserved implementation growing from empty
        // would double past it (1→2→…→64 for 33 entries).
        let a = SparseDelta {
            dim: 100_000,
            idx: (0..17).map(|k| k * 2).collect(),
            val: vec![1.0; 17],
        };
        let b = SparseDelta {
            dim: 100_000,
            idx: (0..16).map(|k| k * 2 + 1).collect(),
            val: vec![1.0; 16],
        };
        let bound = a.nnz() + b.nnz();
        match merge(Delta::Sparse(a), Delta::Sparse(b)) {
            Delta::Sparse(s) => {
                assert_eq!(s.nnz(), bound);
                assert!(
                    s.idx.capacity() <= bound && s.val.capacity() <= bound,
                    "merge reallocated past its reservation: idx cap {} / val cap {} > {bound}",
                    s.idx.capacity(),
                    s.val.capacity()
                );
            }
            Delta::Dense(_) => panic!("33 entries over d=100000 must stay sparse"),
        }
    }

    #[test]
    fn codec_entry_widths_and_breakeven_agree() {
        // The generalized densify rule and message sizing must agree at
        // every (codec, nnz, dim), and the f64 codec must reproduce the
        // legacy single-codec functions exactly.
        assert_eq!(DeltaCodec::F64.sparse_entry_bytes(), SPARSE_ENTRY_BYTES);
        assert_eq!(DeltaCodec::F64.dense_entry_bytes(), DENSE_ENTRY_BYTES);
        assert_eq!(DeltaCodec::F32.sparse_entry_bytes(), 8);
        assert_eq!(DeltaCodec::I16.sparse_entry_bytes(), 6);
        for codec in [DeltaCodec::F64, DeltaCodec::F32, DeltaCodec::I16] {
            for dim in 1..40usize {
                for nnz in 0..=dim {
                    let sparse_bytes = nnz * codec.sparse_entry_bytes();
                    let dense_bytes = dim * codec.dense_entry_bytes();
                    assert_eq!(
                        should_densify_with(codec, nnz, dim),
                        sparse_bytes >= dense_bytes
                    );
                    assert!(
                        sparse_message_elems_with(codec, nnz, dim)
                            <= dense_bytes.div_ceil(DENSE_ENTRY_BYTES)
                    );
                }
            }
        }
        for dim in 1..40usize {
            for nnz in 0..=dim {
                assert_eq!(
                    should_densify(nnz, dim),
                    should_densify_with(DeltaCodec::F64, nnz, dim)
                );
                assert_eq!(
                    sparse_message_elems(nnz, dim),
                    sparse_message_elems_with(DeltaCodec::F64, nnz, dim)
                );
            }
        }
    }

    #[test]
    fn codec_names_roundtrip() {
        for codec in [DeltaCodec::F64, DeltaCodec::F32, DeltaCodec::I16] {
            assert_eq!(DeltaCodec::parse(codec.name()), Some(codec));
        }
        assert_eq!(DeltaCodec::parse("f16"), None);
        assert_eq!(DeltaCodec::default(), DeltaCodec::F64);
    }

    #[test]
    fn i16_step_is_minimal_power_of_two() {
        for_each_case(0x517E9, 200, |g| {
            let max_abs = g.f64_in(1e-12, 1e12);
            let step = i16_step(max_abs);
            // A power of two: log2 is an exact integer.
            let e = step.log2();
            assert_eq!(e, e.floor(), "step {step} not a power of two");
            assert_eq!(step, (2.0f64).powi(e as i32));
            assert!(max_abs / step <= 32767.0, "step {step} too small for {max_abs}");
            assert!(
                max_abs / (step * 0.5) > 32767.0,
                "step {step} not minimal for {max_abs}"
            );
        });
        assert_eq!(i16_step(0.0), 1.0);
        assert_eq!(i16_step(f64::NAN), 1.0);
        assert_eq!(i16_step(f64::INFINITY), 1.0);
    }

    #[test]
    fn f64_codec_is_the_identity() {
        let mut delta = Delta::Sparse(SparseDelta {
            dim: 10,
            idx: vec![1, 7],
            val: vec![0.1, -2.5],
        });
        let want = delta.clone();
        let mut residual = Vec::new();
        compress_delta(&mut delta, DeltaCodec::F64, &mut residual);
        assert_eq!(delta, want);
        assert!(residual.is_empty(), "f64 codec must not touch the residual");
    }

    #[test]
    fn prop_error_feedback_reconstructs_exact_delta() {
        // One compressed round: transmitted image + residual must equal
        // the exact carry (prior residual + this round's delta) bit for
        // bit, for both lossy codecs and both message shapes.
        for_each_case(0xEF_C0DE, 80, |g| {
            let d = g.usize_in(1, 48);
            let codec = if g.bool(0.5) {
                DeltaCodec::F32
            } else {
                DeltaCodec::I16
            };
            let mut residual: Vec<f64> = (0..d)
                .map(|_| if g.bool(0.3) { g.f64_in(-1e-3, 1e-3) } else { 0.0 })
                .collect();
            let dense: Vec<f64> = (0..d)
                .map(|_| if g.bool(0.6) { g.f64_in(-5.0, 5.0) } else { 0.0 })
                .collect();
            let carry: Vec<f64> = dense
                .iter()
                .zip(&residual)
                .map(|(x, r)| x + r)
                .collect();
            let mut delta = if g.bool(0.5) {
                Delta::Dense(dense.clone())
            } else {
                Delta::Sparse(SparseDelta::from_dense(&dense))
            };
            compress_delta(&mut delta, codec, &mut residual);
            let image = delta.clone().into_dense();
            for j in 0..d {
                let reconstructed = image[j] + residual[j];
                assert_eq!(
                    reconstructed.to_bits(),
                    carry[j].to_bits(),
                    "image {} + residual {} != carry {} at {j}",
                    image[j],
                    residual[j],
                    carry[j]
                );
            }
        });
    }

    #[test]
    fn prop_residual_stays_bounded_across_rounds() {
        // Error feedback must not accumulate: after every round the
        // leftover error is at most one quantization step of that
        // round's carry, no matter how many rounds have run.
        for_each_case(0xB0_04D3, 30, |g| {
            let d = g.usize_in(1, 32);
            let codec = if g.bool(0.5) {
                DeltaCodec::F32
            } else {
                DeltaCodec::I16
            };
            let mut residual: Vec<f64> = Vec::new();
            for _round in 0..12 {
                let dense: Vec<f64> = (0..d)
                    .map(|_| if g.bool(0.5) { g.f64_in(-3.0, 3.0) } else { 0.0 })
                    .collect();
                let mut prior = residual.clone();
                prior.resize(d, 0.0);
                let carry_max = dense
                    .iter()
                    .zip(&prior)
                    .map(|(x, r)| (x + r).abs())
                    .fold(0.0f64, f64::max);
                let mut delta = Delta::Dense(dense);
                compress_delta(&mut delta, codec, &mut residual);
                let bound = match codec {
                    // Half an i16 step; the minimal step is < 2·max/32767.
                    DeltaCodec::I16 => i16_step(carry_max),
                    // f32 rounding: half an ulp is ≤ 2⁻²⁴ relative, plus
                    // an absolute floor for the subnormal-f32 zone.
                    _ => carry_max * 1e-6 + 1e-40,
                };
                for (j, r) in residual.iter().enumerate() {
                    assert!(
                        r.abs() <= bound,
                        "round {_round}: residual {r} at {j} exceeds bound {bound}"
                    );
                }
            }
        });
    }

    #[test]
    fn prop_matches_dense_tree_reduce() {
        // Random mixes of dense and sparse messages across random machine
        // counts and densities must match the dense tree reduction within
        // fp tolerance.
        for_each_case(0x5DE17A, 60, |g| {
            let m = g.usize_in(1, 16);
            let d = g.usize_in(1, 40);
            let density = g.f64_in(0.0, 1.0);
            let dense: Vec<Vec<f64>> = (0..m)
                .map(|_| {
                    (0..d)
                        .map(|_| {
                            if g.bool(density) {
                                g.f64_in(-5.0, 5.0)
                            } else {
                                0.0
                            }
                        })
                        .collect()
                })
                .collect();
            let weights = g.vec_f64(m, 0.0, 1.0);
            let want = tree_allreduce(&dense, &weights);
            let deltas: Vec<Delta> = dense
                .iter()
                .map(|v| {
                    if g.bool(0.5) {
                        Delta::Dense(v.clone())
                    } else {
                        Delta::Sparse(SparseDelta::from_dense(v))
                    }
                })
                .collect();
            let got = tree_allreduce_delta(deltas, &weights).0.into_dense();
            for j in 0..d {
                assert!(
                    (got[j] - want[j]).abs() < 1e-9,
                    "sparse tree {} vs dense tree {} at {j}",
                    got[j],
                    want[j]
                );
            }
        });
    }
}
