//! Tree allreduce over per-worker vectors.
//!
//! The global step of Algorithm 2 is a single weighted allreduce
//! `v ← v + Σ_ℓ (n_ℓ/n)·Δv_ℓ`. This module implements the reduction with
//! the same binary-tree round structure an MPI allreduce uses, so the
//! modeled communication rounds in [`super::cost`] correspond one-to-one
//! with what the code actually performs, and tests can validate the tree
//! result against the serial sum.

/// Weighted tree-reduce: returns `Σ_ℓ weight_ℓ · contributions_ℓ`.
///
/// Pairwise binary-tree combination (⌈log₂ m⌉ rounds), matching MPI's
/// recursive halving/doubling order rather than a serial left fold — the
/// floating-point result therefore matches what a real cluster computes.
pub fn tree_allreduce(contributions: &[Vec<f64>], weights: &[f64]) -> Vec<f64> {
    assert_eq!(contributions.len(), weights.len());
    assert!(!contributions.is_empty());
    let d = contributions[0].len();
    let mut buf: Vec<Vec<f64>> = contributions
        .iter()
        .zip(weights)
        .map(|(c, &w)| {
            assert_eq!(c.len(), d, "ragged contribution");
            c.iter().map(|x| w * x).collect()
        })
        .collect();
    let mut stride = 1usize;
    while stride < buf.len() {
        let mut i = 0;
        while i + stride < buf.len() {
            let (left, right) = buf.split_at_mut(i + stride);
            let dst = &mut left[i];
            let src = &right[0];
            for (a, b) in dst.iter_mut().zip(src) {
                *a += b;
            }
            i += stride * 2;
        }
        stride *= 2;
    }
    std::mem::take(&mut buf[0])
}

/// Number of tree rounds an allreduce over `m` machines takes.
pub fn rounds(m: usize) -> usize {
    (usize::BITS - (m.max(1) - 1).leading_zeros()) as usize
}

/// Pairwise binary-tree sum of scalars — the same stride-doubling
/// combination order as [`tree_allreduce`], applied to the per-machine
/// scalar legs (duality-gap loss/conjugate sums).
///
/// Why not a left fold: the hierarchical backends (DESIGN.md §10) reduce
/// `T` sub-shard sums inside each machine and then `m` machine sums at
/// the coordinator. A pairwise tree over `m·T` leaves factors *exactly*
/// into tree-over-`T` followed by tree-over-`m` whenever `T` is a power
/// of two (the flat tree's first `log₂ T` levels never cross a
/// `T`-aligned block boundary), so a nested `(m, T)` evaluation is
/// bit-identical to a flat `m·T` one — a left fold has no such
/// factorization. Pinned by `tree_sum_factors_hierarchically`.
pub fn tree_sum(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut buf = xs.to_vec();
    let mut stride = 1usize;
    while stride < buf.len() {
        let mut i = 0;
        while i + stride < buf.len() {
            buf[i] += buf[i + stride];
            i += stride * 2;
        }
        stride *= 2;
    }
    buf[0]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::for_each_case;

    #[test]
    fn matches_serial_sum() {
        let contribs = vec![vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]];
        let w = vec![0.5, 0.25, 0.25];
        let got = tree_allreduce(&contribs, &w);
        assert_eq!(got, vec![0.5 + 0.75 + 1.25, 1.0 + 1.0 + 1.5]);
    }

    #[test]
    fn single_contribution_scaled() {
        assert_eq!(tree_allreduce(&[vec![2.0]], &[0.5]), vec![1.0]);
    }

    #[test]
    fn rounds_is_ceil_log2() {
        assert_eq!(rounds(1), 0);
        assert_eq!(rounds(2), 1);
        assert_eq!(rounds(3), 2);
        assert_eq!(rounds(8), 3);
        assert_eq!(rounds(9), 4);
    }

    #[test]
    fn tree_sum_matches_serial_within_fp_tolerance() {
        for_each_case(0x75F, 50, |g| {
            let n = g.usize_in(0, 40);
            let xs = g.vec_f64(n, -10.0, 10.0);
            let serial: f64 = xs.iter().sum();
            assert!((tree_sum(&xs) - serial).abs() < 1e-9);
        });
        assert_eq!(tree_sum(&[]), 0.0);
        assert_eq!(tree_sum(&[3.5]), 3.5);
    }

    #[test]
    fn tree_sum_matches_tree_allreduce_scalar() {
        // Same combination structure as the vector reduce with unit
        // weights (the property the eval legs rely on) — up to the
        // 1.0-scaling no-op, which is bitwise identity.
        for_each_case(0x75E, 30, |g| {
            let m = g.usize_in(1, 20);
            let xs = g.vec_f64(m, -5.0, 5.0);
            let contribs: Vec<Vec<f64>> = xs.iter().map(|&x| vec![x]).collect();
            let want = tree_allreduce(&contribs, &vec![1.0; m])[0];
            assert_eq!(tree_sum(&xs).to_bits(), want.to_bits());
        });
    }

    #[test]
    fn tree_sum_factors_hierarchically() {
        // For power-of-two block sizes T, tree over m·T leaves ==
        // tree-over-T per block then tree-over-m — bitwise. This is the
        // (m, T)-vs-flat-m·T eval-leg parity of DESIGN.md §10.
        for_each_case(0x75D, 40, |g| {
            let t = 1usize << g.usize_in(0, 4); // 1, 2, 4, 8
            let m = g.usize_in(1, 6);
            let xs = g.vec_f64(m * t, -5.0, 5.0);
            let flat = tree_sum(&xs);
            let blocked: Vec<f64> = xs.chunks(t).map(tree_sum).collect();
            assert_eq!(
                flat.to_bits(),
                tree_sum(&blocked).to_bits(),
                "m={m} t={t}"
            );
        });
    }

    #[test]
    fn prop_tree_equals_serial_within_fp_tolerance() {
        for_each_case(0xA77, 50, |g| {
            let m = g.usize_in(1, 20);
            let d = g.usize_in(1, 30);
            let contribs: Vec<Vec<f64>> =
                (0..m).map(|_| g.vec_f64(d, -10.0, 10.0)).collect();
            let weights = g.vec_f64(m, 0.0, 1.0);
            let got = tree_allreduce(&contribs, &weights);
            for j in 0..d {
                let serial: f64 = (0..m).map(|l| weights[l] * contribs[l][j]).sum();
                assert!(
                    (got[j] - serial).abs() < 1e-9,
                    "tree {} vs serial {serial}",
                    got[j]
                );
            }
        });
    }
}
