//! Tree allreduce over per-worker vectors.
//!
//! The global step of Algorithm 2 is a single weighted allreduce
//! `v ← v + Σ_ℓ (n_ℓ/n)·Δv_ℓ`. This module implements the reduction with
//! the same binary-tree round structure an MPI allreduce uses, so the
//! modeled communication rounds in [`super::cost`] correspond one-to-one
//! with what the code actually performs, and tests can validate the tree
//! result against the serial sum.

/// Weighted tree-reduce: returns `Σ_ℓ weight_ℓ · contributions_ℓ`.
///
/// Pairwise binary-tree combination (⌈log₂ m⌉ rounds), matching MPI's
/// recursive halving/doubling order rather than a serial left fold — the
/// floating-point result therefore matches what a real cluster computes.
pub fn tree_allreduce(contributions: &[Vec<f64>], weights: &[f64]) -> Vec<f64> {
    assert_eq!(contributions.len(), weights.len());
    assert!(!contributions.is_empty());
    let d = contributions[0].len();
    let mut buf: Vec<Vec<f64>> = contributions
        .iter()
        .zip(weights)
        .map(|(c, &w)| {
            assert_eq!(c.len(), d, "ragged contribution");
            c.iter().map(|x| w * x).collect()
        })
        .collect();
    let mut stride = 1usize;
    while stride < buf.len() {
        let mut i = 0;
        while i + stride < buf.len() {
            let (left, right) = buf.split_at_mut(i + stride);
            let dst = &mut left[i];
            let src = &right[0];
            for (a, b) in dst.iter_mut().zip(src) {
                *a += b;
            }
            i += stride * 2;
        }
        stride *= 2;
    }
    std::mem::take(&mut buf[0])
}

/// Number of tree rounds an allreduce over `m` machines takes.
pub fn rounds(m: usize) -> usize {
    (usize::BITS - (m.max(1) - 1).leading_zeros()) as usize
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::for_each_case;

    #[test]
    fn matches_serial_sum() {
        let contribs = vec![vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]];
        let w = vec![0.5, 0.25, 0.25];
        let got = tree_allreduce(&contribs, &w);
        assert_eq!(got, vec![0.5 + 0.75 + 1.25, 1.0 + 1.0 + 1.5]);
    }

    #[test]
    fn single_contribution_scaled() {
        assert_eq!(tree_allreduce(&[vec![2.0]], &[0.5]), vec![1.0]);
    }

    #[test]
    fn rounds_is_ceil_log2() {
        assert_eq!(rounds(1), 0);
        assert_eq!(rounds(2), 1);
        assert_eq!(rounds(3), 2);
        assert_eq!(rounds(8), 3);
        assert_eq!(rounds(9), 4);
    }

    #[test]
    fn prop_tree_equals_serial_within_fp_tolerance() {
        for_each_case(0xA77, 50, |g| {
            let m = g.usize_in(1, 20);
            let d = g.usize_in(1, 30);
            let contribs: Vec<Vec<f64>> =
                (0..m).map(|_| g.vec_f64(d, -10.0, 10.0)).collect();
            let weights = g.vec_f64(m, 0.0, 1.0);
            let got = tree_allreduce(&contribs, &weights);
            for j in 0..d {
                let serial: f64 = (0..m).map(|l| weights[l] * contribs[l][j]).sum();
                assert!(
                    (got[j] - serial).abs() < 1e-9,
                    "tree {} vs serial {serial}",
                    got[j]
                );
            }
        });
    }
}
