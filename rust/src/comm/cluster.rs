//! Worker execution: run a closure on every machine, serially or on real
//! OS threads, returning per-worker results plus the modeled parallel
//! compute time (`max_ℓ t_ℓ` — the machines run concurrently).

use std::time::Instant;

/// Execution backend for the per-machine local steps.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Cluster {
    /// Deterministic serial execution; parallel wall-clock is *modeled*
    /// as the max over per-worker compute times.
    Serial,
    /// Real `std::thread::scope` parallelism (one thread per machine).
    Threads,
}

/// Outcome of one parallel section.
#[derive(Debug)]
pub struct ParallelRun<T> {
    /// Per-worker results, in machine order.
    pub results: Vec<T>,
    /// Modeled parallel time: `max_ℓ` of per-worker elapsed seconds.
    pub parallel_secs: f64,
    /// Total CPU work: `Σ_ℓ` of per-worker elapsed seconds.
    pub total_secs: f64,
}

impl Cluster {
    /// Run `f(l, &mut states[l])` for every machine `l`.
    pub fn run<S, T, F>(&self, states: &mut [S], f: F) -> ParallelRun<T>
    where
        S: Send,
        T: Send,
        F: Fn(usize, &mut S) -> T + Sync,
    {
        match self {
            Cluster::Serial => {
                let mut results = Vec::with_capacity(states.len());
                let mut times = Vec::with_capacity(states.len());
                for (l, s) in states.iter_mut().enumerate() {
                    let t0 = Instant::now();
                    results.push(f(l, s));
                    times.push(t0.elapsed().as_secs_f64());
                }
                ParallelRun {
                    results,
                    parallel_secs: times.iter().cloned().fold(0.0, f64::max),
                    total_secs: times.iter().sum(),
                }
            }
            Cluster::Threads => {
                let mut slots: Vec<Option<(T, f64)>> =
                    (0..states.len()).map(|_| None).collect();
                std::thread::scope(|scope| {
                    for ((l, s), slot) in states.iter_mut().enumerate().zip(slots.iter_mut()) {
                        let f = &f;
                        scope.spawn(move || {
                            let t0 = Instant::now();
                            let r = f(l, s);
                            *slot = Some((r, t0.elapsed().as_secs_f64()));
                        });
                    }
                });
                let mut results = Vec::with_capacity(slots.len());
                let mut parallel_secs = 0.0f64;
                let mut total_secs = 0.0f64;
                for slot in slots {
                    let (r, t) = slot.expect("worker thread panicked");
                    results.push(r);
                    parallel_secs = parallel_secs.max(t);
                    total_secs += t;
                }
                ParallelRun {
                    results,
                    parallel_secs,
                    total_secs,
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serial_and_threads_compute_same_results() {
        let mut a = vec![1u64, 2, 3, 4];
        let mut b = a.clone();
        let f = |l: usize, s: &mut u64| {
            *s += l as u64;
            *s * 10
        };
        let ra = Cluster::Serial.run(&mut a, f);
        let rb = Cluster::Threads.run(&mut b, f);
        assert_eq!(ra.results, rb.results);
        assert_eq!(a, b);
        assert_eq!(ra.results, vec![10, 30, 50, 70]);
    }

    #[test]
    fn parallel_time_is_max_total_is_sum() {
        let mut s = vec![(); 3];
        let r = Cluster::Serial.run(&mut s, |l, _| {
            std::thread::sleep(std::time::Duration::from_millis(2 * (l as u64 + 1)));
        });
        assert!(r.parallel_secs >= 0.005 && r.parallel_secs < 0.1);
        assert!(r.total_secs >= r.parallel_secs);
    }

    #[test]
    fn threads_actually_overlap() {
        let mut s = vec![(); 4];
        let t0 = Instant::now();
        let r = Cluster::Threads.run(&mut s, |_, _| {
            std::thread::sleep(std::time::Duration::from_millis(20));
        });
        let wall = t0.elapsed().as_secs_f64();
        // 4×20 ms serially would be 80 ms; overlapped should be well under.
        assert!(wall < 0.06, "threads did not overlap: {wall}s");
        assert!(r.total_secs > 0.07);
    }

    #[test]
    fn empty_states() {
        let mut s: Vec<u8> = vec![];
        let r = Cluster::Serial.run(&mut s, |_, _| 0u8);
        assert!(r.results.is_empty());
        assert_eq!(r.parallel_secs, 0.0);
    }
}
