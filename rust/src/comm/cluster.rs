//! Worker execution: run a closure on every machine, serially or on the
//! persistent worker pool, returning per-worker results plus the modeled
//! parallel compute time (`max_ℓ t_ℓ` — the machines run concurrently).
//!
//! The third backend, [`Cluster::Tcp`], hosts every machine in a real
//! OS *process* reached over sockets; closures cannot cross that
//! boundary, so the coordinators route their machine operations through
//! the typed wire ops of [`super::tcp::TcpHandle`] instead of
//! [`Cluster::run`] (which panics on the TCP variant by design — any
//! closure reaching it is a coordinator bug, not a runtime condition).

use std::time::Instant;

use super::pool::WorkerPool;
use super::tcp::TcpHandle;

/// Execution backend for the per-machine local steps.
#[derive(Clone, Debug)]
pub enum Cluster {
    /// Deterministic serial execution; parallel wall-clock is *modeled*
    /// as the max over per-worker compute times.
    Serial,
    /// Real OS-thread parallelism on the persistent work-stealing
    /// [`WorkerPool`] (long-lived threads reused across rounds; any free
    /// thread may pick up any machine or sub-machine leg).
    Threads,
    /// Real multi-process coordinator/worker TCP transport
    /// (DESIGN.md §9): one OS process per machine, length-prefixed
    /// binary frames, actual wire bytes recorded.
    Tcp(TcpHandle),
}

impl PartialEq for Cluster {
    fn eq(&self, other: &Self) -> bool {
        match (self, other) {
            (Cluster::Serial, Cluster::Serial) | (Cluster::Threads, Cluster::Threads) => true,
            (Cluster::Tcp(a), Cluster::Tcp(b)) => a.same_cluster(b),
            _ => false,
        }
    }
}

impl Eq for Cluster {}

/// Outcome of one parallel section.
#[derive(Debug)]
pub struct ParallelRun<T> {
    /// Per-worker results, in machine order.
    pub results: Vec<T>,
    /// Modeled parallel time: `max_ℓ` of per-worker elapsed seconds.
    pub parallel_secs: f64,
    /// Total CPU work: `Σ_ℓ` of per-worker elapsed seconds.
    pub total_secs: f64,
}

impl Cluster {
    /// The remote transport handle, when the machines live in other OS
    /// processes — the **one** dispatch point coordinators branch on:
    /// `Some` routes an operation through the typed wire ops, `None`
    /// runs it in-process via [`Cluster::run`]. (The handle locks
    /// internally, so a shared reference carries full wire-op access.)
    pub fn remote(&self) -> Option<&TcpHandle> {
        match self {
            Cluster::Tcp(h) => Some(h),
            _ => None,
        }
    }

    /// Whether solver state can be checkpointed/restored on this
    /// backend. Remote workers own their dual variables — the
    /// coordinator cannot serialize state it does not hold — so only
    /// the in-process backends support it (fault tolerance for remote
    /// workers is the §14 resurrection protocol instead).
    pub fn supports_checkpoint(&self) -> bool {
        self.remote().is_none()
    }

    /// Whether per-machine [`WorkerState`]s are observable in this
    /// process (state introspection, invariant checks, direct dual
    /// reads). False for the remote backend, where that state lives in
    /// other processes.
    ///
    /// [`WorkerState`]: crate::solver::WorkerState
    pub fn has_local_workers(&self) -> bool {
        self.remote().is_none()
    }

    /// Whether a machine's *intra*-machine legs (sub-shard solvers, eval
    /// passes — DESIGN.md §10) should run on real threads. `Serial`
    /// executes sub-shards serially (deterministic, parallelism modeled
    /// as `max`); `Threads` publishes them to the shared work-stealing
    /// injector ([`WorkerPool`] nested dispatch), where any idle pool
    /// thread may pick them up. The TCP variant never reaches this —
    /// remote workers decide locally in their own processes.
    pub fn parallel_local(&self) -> bool {
        matches!(self, Cluster::Threads)
    }

    /// Run `f(l, &mut states[l])` for every machine `l` (in-process
    /// backends only — see the module docs for the TCP variant).
    pub fn run<S, T, F>(&self, states: &mut [S], f: F) -> ParallelRun<T>
    where
        S: Send,
        T: Send,
        F: Fn(usize, &mut S) -> T + Sync,
    {
        match self {
            // dadm-lint: allow(total-decoding) — by-design coordinator-bug guard (see module docs); closures cannot cross a process boundary
            Cluster::Tcp(_) => panic!(
                "Cluster::Tcp cannot execute closures; route this operation \
                 through the TcpHandle wire ops (coordinator bug)"
            ),
            Cluster::Serial => {
                let mut results = Vec::with_capacity(states.len());
                let mut times = Vec::with_capacity(states.len());
                for (l, s) in states.iter_mut().enumerate() {
                    // dadm-lint: allow(wall-clock) — per-leg compute timing for the cost model; reported, never control flow
                    let t0 = Instant::now();
                    results.push(f(l, s));
                    times.push(t0.elapsed().as_secs_f64());
                }
                ParallelRun {
                    results,
                    parallel_secs: times.iter().cloned().fold(0.0, f64::max),
                    // dadm-lint: allow(naive-reduction) — local timing accounting, not cross-machine float math
                    total_secs: times.iter().sum(),
                }
            }
            Cluster::Threads => WorkerPool::global().run(states, f),
        }
    }
}

/// Run one machine's intra-machine parallel section: `f(k, &mut
/// subs[k])` for every sub-shard `k`. With `parallel = false` (the
/// `Serial` backend) the legs run serially on the calling thread; with
/// `parallel = true` they go to the worker pool's shared injector —
/// nested at depth 2 from inside a pool job, a top-level section from a
/// plain thread (a remote TCP worker process) — where idle threads steal
/// them. Single-sub groups always run inline. `parallel_secs` is the
/// modeled machine time: the max over sub-shard legs, i.e. the wall time
/// of a `T`-thread machine.
pub fn run_subgroup<S, T, F>(parallel: bool, subs: &mut [S], f: F) -> ParallelRun<T>
where
    S: Send,
    T: Send,
    F: Fn(usize, &mut S) -> T + Sync,
{
    if parallel && subs.len() > 1 {
        WorkerPool::global().run(subs, f)
    } else {
        super::pool::run_inline(subs, &f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serial_and_threads_compute_same_results() {
        let mut a = vec![1u64, 2, 3, 4];
        let mut b = a.clone();
        let f = |l: usize, s: &mut u64| {
            *s += l as u64;
            *s * 10
        };
        let ra = Cluster::Serial.run(&mut a, f);
        let rb = Cluster::Threads.run(&mut b, f);
        assert_eq!(ra.results, rb.results);
        assert_eq!(a, b);
        assert_eq!(ra.results, vec![10, 30, 50, 70]);
    }

    #[test]
    fn parallel_time_is_max_total_is_sum() {
        // Structural assertions only: `sleep` guarantees a *minimum*, so
        // lower bounds are safe on any machine, while upper bounds on
        // wall-clock are not (loaded CI boxes oversleep freely).
        let mut s = vec![(); 3];
        let r = Cluster::Serial.run(&mut s, |l, _| {
            std::thread::sleep(std::time::Duration::from_millis(5 * (l as u64 + 1)));
        });
        // Sleeps of 5/10/15 ms: max ≥ 15 ms, sum ≥ 30 ms (small slack for
        // timer granularity), and max ≤ sum always.
        assert!(r.parallel_secs >= 0.014, "max sleep: {}", r.parallel_secs);
        assert!(r.total_secs >= 0.029, "sum of sleeps: {}", r.total_secs);
        assert!(r.total_secs >= r.parallel_secs);
    }

    #[test]
    fn threads_actually_overlap() {
        // Four workers each sleep 60 ms: run serially that is ≥ 240 ms of
        // wall clock. Overlap is asserted as a *ratio* of measured work to
        // wall time — sleeps need no CPU, so even a heavily loaded machine
        // overlaps them — with a generous 0.75 margin (ideal is 0.25).
        let mut s = vec![(); 4];
        let t0 = Instant::now();
        let r = Cluster::Threads.run(&mut s, |_, _| {
            std::thread::sleep(std::time::Duration::from_millis(60));
        });
        let wall = t0.elapsed().as_secs_f64();
        assert!(r.total_secs >= 0.9 * 0.24, "four 60 ms sleeps: {}", r.total_secs);
        assert!(
            wall < 0.75 * r.total_secs,
            "threads did not overlap: wall {wall}s vs total {}s",
            r.total_secs
        );
        assert!(r.parallel_secs <= r.total_secs);
    }

    #[test]
    fn empty_states() {
        let mut s: Vec<u8> = vec![];
        let r = Cluster::Serial.run(&mut s, |_, _| 0u8);
        assert!(r.results.is_empty());
        assert_eq!(r.parallel_secs, 0.0);
    }

    #[test]
    fn run_subgroup_serial_and_parallel_agree() {
        let f = |k: usize, s: &mut u64| {
            *s += k as u64;
            *s * 2
        };
        let mut a = vec![5u64, 6, 7];
        let mut b = a.clone();
        let ra = run_subgroup(false, &mut a, f);
        let rb = run_subgroup(true, &mut b, f);
        assert_eq!(ra.results, rb.results);
        assert_eq!(a, b);
        assert_eq!(ra.results, vec![10, 14, 18]);
    }

    #[test]
    fn run_subgroup_nests_inside_cluster_run() {
        // The exact shape of a hierarchical round: a machine-level pool
        // section whose jobs each open a sub-shard section.
        let mut groups: Vec<Vec<u64>> = vec![vec![1, 2], vec![3, 4], vec![5, 6]];
        let r = Cluster::Threads.run(&mut groups, |_, g| {
            run_subgroup(true, g, |_, x| *x * 10).results.iter().sum::<u64>()
        });
        assert_eq!(r.results, vec![30, 70, 110]);
    }

    #[test]
    fn parallel_local_only_for_threads() {
        assert!(!Cluster::Serial.parallel_local());
        assert!(Cluster::Threads.parallel_local());
    }
}
