//! Alpha-beta communication cost model.
//!
//! A communication round moving `b` bytes among `m` machines through a
//! binary reduce+broadcast tree costs
//!
//! ```text
//! T = 2·⌈log₂ m⌉·α  +  2·b·β
//! ```
//!
//! with `α` the per-message latency and `β` the inverse bandwidth
//! (seconds/byte). Defaults model the commodity-Ethernet private-cloud
//! cluster of §10 (α = 100 µs, 1 GbE ⇒ β = 8 ns/byte), and the benches
//! expose both knobs so Figures 9/11 ("Comm. Time" in green) can be
//! regenerated under different fabrics.

/// Latency/bandwidth communication model.
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    /// Per-message latency in seconds.
    pub alpha: f64,
    /// Inverse bandwidth in seconds per byte.
    pub beta: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel {
            alpha: 100e-6,
            beta: 8e-9,
        }
    }
}

impl CostModel {
    /// A zero-cost model (pure algorithmic comparisons).
    pub fn free() -> Self {
        CostModel {
            alpha: 0.0,
            beta: 0.0,
        }
    }

    /// Modeled time of one allreduce of `elems` f64 values over `m`
    /// machines.
    pub fn allreduce_time(&self, m: usize, elems: usize) -> f64 {
        if m <= 1 {
            return 0.0;
        }
        let hops = (m as f64).log2().ceil();
        2.0 * hops * self.alpha + 2.0 * (elems * 8) as f64 * self.beta
    }

    /// Modeled time of a leader broadcast of `elems` f64 values.
    pub fn broadcast_time(&self, m: usize, elems: usize) -> f64 {
        if m <= 1 {
            return 0.0;
        }
        let hops = (m as f64).log2().ceil();
        hops * self.alpha + (elems * 8) as f64 * self.beta
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_machine_is_free() {
        let c = CostModel::default();
        assert_eq!(c.allreduce_time(1, 1_000_000), 0.0);
        assert_eq!(c.broadcast_time(1, 1_000_000), 0.0);
    }

    #[test]
    fn grows_with_machines_and_size() {
        let c = CostModel::default();
        assert!(c.allreduce_time(16, 100) > c.allreduce_time(4, 100));
        assert!(c.allreduce_time(4, 10_000) > c.allreduce_time(4, 100));
    }

    #[test]
    fn latency_dominates_small_messages() {
        let c = CostModel::default();
        // 8-byte message at m=2: latency term 2·1·100µs ≫ bandwidth term.
        let t = c.allreduce_time(2, 1);
        assert!((t - (2.0 * 100e-6 + 2.0 * 8.0 * 8e-9)).abs() < 1e-12);
    }

    #[test]
    fn free_model_is_zero() {
        let c = CostModel::free();
        assert_eq!(c.allreduce_time(32, 1 << 20), 0.0);
    }
}
