//! Elastic-net regularizer `g(w) = ½‖w‖₂² + τ‖w‖₁` (1-strongly convex).
//!
//! This is the paper's experimental `g` with `τ = μ/λ` (§10, "we choose
//! `λg(w) = (λ/2)‖w‖² + μ‖w‖₁`"); `τ = 0` gives plain L2. Closed forms:
//!
//! * `∇g*(v) = soft_threshold(v, τ)` elementwise,
//! * `g*(v) = ½‖soft_threshold(v, τ)‖²`.
//!
//! The Acc-DADM inner problem replaces `g` by
//! `f(w) = (λ/λ̃)g(w) + (κ/2λ̃)‖w‖²  = ½‖w‖² + (μ/λ̃)‖w‖₁` (§9.8), i.e.
//! *another* `ElasticNet` with `τ = μ/λ̃` — constructed by the coordinator
//! via [`ElasticNet::new`].

use super::Regularizer;
use crate::utils::math::{l1_norm, l2_norm_sq, soft_threshold_scalar};

/// `g(w) = ½‖w‖² + τ‖w‖₁`.
#[derive(Clone, Copy, Debug)]
pub struct ElasticNet {
    tau: f64,
}

impl ElasticNet {
    /// Build with L1 weight `τ ≥ 0`.
    pub fn new(tau: f64) -> Self {
        assert!(tau >= 0.0 && tau.is_finite(), "invalid τ = {tau}");
        ElasticNet { tau }
    }

    /// Plain L2: `g(w) = ½‖w‖²`.
    pub fn l2() -> Self {
        ElasticNet::new(0.0)
    }

    /// The L1 weight τ.
    pub fn tau(&self) -> f64 {
        self.tau
    }
}

impl Regularizer for ElasticNet {
    fn value(&self, w: &[f64]) -> f64 {
        0.5 * l2_norm_sq(w) + self.tau * l1_norm(w)
    }

    fn conj(&self, v: &[f64]) -> f64 {
        v.iter()
            .map(|&vj| {
                let wj = soft_threshold_scalar(vj, self.tau);
                0.5 * wj * wj
            })
            .sum()
    }

    fn grad_conj_at(&self, _j: usize, vj: f64) -> f64 {
        soft_threshold_scalar(vj, self.tau)
    }

    fn grad_conj_into(&self, v: &[f64], w: &mut [f64]) {
        debug_assert_eq!(v.len(), w.len());
        if self.tau == 0.0 {
            w.copy_from_slice(v);
        } else {
            for (wj, &vj) in w.iter_mut().zip(v) {
                *wj = soft_threshold_scalar(vj, self.tau);
            }
        }
    }

    fn wire_spec(&self) -> Option<crate::comm::wire::WireReg> {
        Some(crate::comm::wire::WireReg::ElasticNet(*self))
    }

    fn name(&self) -> &'static str {
        "elastic_net"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::for_each_case;

    #[test]
    fn l2_special_case_is_identity_map() {
        let r = ElasticNet::l2();
        let v = vec![1.5, -2.0, 0.0];
        assert_eq!(r.grad_conj(&v), v);
        assert_eq!(r.conj(&v), 0.5 * (1.5f64 * 1.5 + 4.0));
        assert_eq!(r.value(&v), r.conj(&v)); // self-conjugate
    }

    #[test]
    fn grad_conj_soft_thresholds() {
        let r = ElasticNet::new(1.0);
        assert_eq!(r.grad_conj(&[2.0, -2.0, 0.5]), vec![1.0, -1.0, 0.0]);
    }

    #[test]
    fn conj_matches_sup_definition() {
        // g*(v) = sup_w vᵀw − g(w), checked by 1-D grid (g separable).
        let r = ElasticNet::new(0.7);
        for_each_case(0xA1, 50, |g| {
            let v = g.f64_in(-3.0, 3.0);
            let mut best = f64::NEG_INFINITY;
            let mut w = -5.0;
            while w <= 5.0 {
                best = best.max(v * w - 0.5 * w * w - 0.7 * w.abs());
                w += 1e-4;
            }
            let got = r.conj(&[v]);
            assert!((got - best).abs() < 1e-6, "g*({v}) = {got}, grid {best}");
        });
    }

    #[test]
    fn value_is_one_strongly_convex() {
        // g(w) − ½‖w‖² = τ‖w‖₁ convex ⇒ strong convexity modulus exactly 1;
        // spot-check the inequality g(b) ≥ g(a) + ∂g(a)ᵀ(b−a) + ½‖b−a‖².
        let r = ElasticNet::new(0.3);
        for_each_case(0xA2, 100, |g| {
            let d = g.usize_in(1, 6);
            let a = g.vec_f64(d, -2.0, 2.0);
            let b = g.vec_f64(d, -2.0, 2.0);
            // subgradient of g at a: a + τ·sign(a) (choose 0 at 0)
            let sub: Vec<f64> = a.iter().map(|&x| x + 0.3 * x.signum()).collect();
            let lin: f64 = sub.iter().zip(b.iter().zip(&a)).map(|(s, (x, y))| s * (x - y)).sum();
            let quad: f64 = b.iter().zip(&a).map(|(x, y)| (x - y) * (x - y)).sum::<f64>() * 0.5;
            assert!(r.value(&b) + 1e-9 >= r.value(&a) + lin + quad);
        });
    }

    #[test]
    #[should_panic]
    fn rejects_negative_tau() {
        ElasticNet::new(-0.1);
    }
}
