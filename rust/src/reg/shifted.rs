//! Linearly-shifted elastic net — the Acc-DADM inner regularizer.
//!
//! Algorithm 3 solves, at stage `t`, the proximal-point objective
//! `P_t(w) = Σφ_i + λn·g(w) + h(w) + (κn/2)‖w − y^{t−1}‖²`. Following
//! §9.8 ("Dual subproblems in Acc-DADM") with `λ̃ = λ + κ` and
//! `f(w) = (λ/λ̃)g(w) + (κ/2λ̃)‖w‖²`, the inner problem is a *standard*
//! DADM instance with effective regularization `λ̃` and regularizer
//!
//! ```text
//! g_t(w) = f(w) − sᵀw,     s = (κ/λ̃)·y^{t−1}
//!        = ½‖w‖² + (μ/λ̃)‖w‖₁ − sᵀw      (for the experiments' g)
//! ```
//!
//! (dropping the additive constant `(κn/2)‖y‖²`, which cancels in the
//! duality gap). `g_t` is still 1-strongly convex and its conjugate maps
//! are those of the elastic net evaluated at `v + s`:
//! `g_t*(v) = f*(v+s)`, `∇g_t*(v) = soft_threshold(v + s, μ/λ̃)`.

use super::{ElasticNet, Regularizer};
use crate::utils::math::{dot, soft_threshold_scalar};

/// `g(w) − shiftᵀw` with `g` an [`ElasticNet`].
#[derive(Clone, Debug)]
pub struct ShiftedElasticNet {
    base: ElasticNet,
    shift: Vec<f64>,
}

impl ShiftedElasticNet {
    /// Build from the base elastic net and the shift vector `s`.
    pub fn new(base: ElasticNet, shift: Vec<f64>) -> Self {
        ShiftedElasticNet { base, shift }
    }

    /// The Acc-DADM stage regularizer: `τ = μ/λ̃`, `s = (κ/λ̃)·y`.
    pub fn acc_stage(mu: f64, lambda_tilde: f64, kappa: f64, y: &[f64]) -> Self {
        let shift = y.iter().map(|&yj| kappa / lambda_tilde * yj).collect();
        ShiftedElasticNet::new(ElasticNet::new(mu / lambda_tilde), shift)
    }

    /// The shift vector `s`.
    pub fn shift(&self) -> &[f64] {
        &self.shift
    }

    /// The base elastic net.
    pub fn base(&self) -> &ElasticNet {
        &self.base
    }
}

impl Regularizer for ShiftedElasticNet {
    fn value(&self, w: &[f64]) -> f64 {
        self.base.value(w) - dot(&self.shift, w)
    }

    fn conj(&self, v: &[f64]) -> f64 {
        debug_assert_eq!(v.len(), self.shift.len());
        let tau = self.base.tau();
        v.iter()
            .zip(&self.shift)
            .map(|(&vj, &sj)| {
                let wj = soft_threshold_scalar(vj + sj, tau);
                0.5 * wj * wj
            })
            .sum()
    }

    fn grad_conj_at(&self, j: usize, vj: f64) -> f64 {
        soft_threshold_scalar(vj + self.shift[j], self.base.tau())
    }

    fn grad_conj_into(&self, v: &[f64], w: &mut [f64]) {
        debug_assert_eq!(v.len(), self.shift.len());
        let tau = self.base.tau();
        for ((wj, &vj), &sj) in w.iter_mut().zip(v).zip(&self.shift) {
            *wj = soft_threshold_scalar(vj + sj, tau);
        }
    }

    fn wire_spec(&self) -> Option<crate::comm::wire::WireReg> {
        Some(crate::comm::wire::WireReg::Shifted(self.clone()))
    }

    fn name(&self) -> &'static str {
        "shifted_elastic_net"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::for_each_case;

    #[test]
    fn zero_shift_equals_base() {
        let base = ElasticNet::new(0.4);
        let s = ShiftedElasticNet::new(base, vec![0.0; 3]);
        let v = vec![1.0, -2.0, 0.2];
        assert_eq!(s.conj(&v), base.conj(&v));
        assert_eq!(s.grad_conj(&v), base.grad_conj(&v));
        let w = vec![0.3, -0.7, 1.1];
        assert_eq!(s.value(&w), base.value(&w));
    }

    #[test]
    fn fenchel_young_with_shift() {
        for_each_case(0xC1, 100, |g| {
            let d = g.usize_in(1, 8);
            let shift = g.vec_f64(d, -1.0, 1.0);
            let reg = ShiftedElasticNet::new(ElasticNet::new(0.3), shift);
            let v = g.vec_f64(d, -3.0, 3.0);
            let w_star = reg.grad_conj(&v);
            let eq = reg.value(&w_star) + reg.conj(&v) - dot(&w_star, &v);
            assert!(eq.abs() < 1e-9, "FY equality violated: {eq}");
            let w_other = g.vec_f64(d, -3.0, 3.0);
            let ineq = reg.value(&w_other) + reg.conj(&v) - dot(&w_other, &v);
            assert!(ineq >= -1e-9);
        });
    }

    #[test]
    fn acc_stage_matches_section_9_8() {
        // λ̃·g_t(w) must equal λ·g(w) + (κ/2)‖w‖² − κ·yᵀw for the
        // experiments' g (up to the dropped κ/2‖y‖² constant).
        for_each_case(0xC2, 50, |g| {
            let d = g.usize_in(1, 6);
            let (lambda, kappa, mu) = (
                g.f64_log_in(1e-8, 1e-2),
                g.f64_log_in(1e-6, 1.0),
                g.f64_log_in(1e-7, 1e-3),
            );
            let lt = lambda + kappa;
            let y = g.vec_f64(d, -1.0, 1.0);
            let w = g.vec_f64(d, -2.0, 2.0);
            let stage = ShiftedElasticNet::acc_stage(mu, lt, kappa, &y);
            let lhs = lt * stage.value(&w);
            let g_orig = ElasticNet::new(mu / lambda);
            let rhs = lambda * g_orig.value(&w)
                + kappa / 2.0 * crate::utils::math::l2_norm_sq(&w)
                - kappa * dot(&y, &w);
            assert!((lhs - rhs).abs() < 1e-9, "{lhs} vs {rhs}");
        });
    }
}
