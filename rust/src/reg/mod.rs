//! Regularizers: the strongly convex `g` and the extra convex term `h`
//! of the paper's primal problem `P(w) = Σφ_i(X_iᵀw) + λn·g(w) + h(w)`.
//!
//! The experiments (§10) use `λ·g(w) = (λ/2)‖w‖² + μ‖w‖₁` with `h = 0`;
//! §6 motivates the `g`/`h` split with sparse group lasso, where the group
//! norm goes into `h` so the *local* updates keep closed form and only the
//! (rare) global synchronization step pays for the group prox. Both are
//! implemented: [`ElasticNet`] for `g`, [`GroupLasso`]/[`Zero`] for `h`.

mod elastic_net;
mod extra;
mod shifted;

pub use elastic_net::ElasticNet;
pub use extra::{ExtraReg, GroupLasso, Zero};
pub use shifted::ShiftedElasticNet;

/// A 1-strongly-convex regularizer `g` with the conjugate-side maps the
/// dual solvers need.
///
/// All `g` in this crate are *separable* (`∇g*` acts elementwise), which
/// the sequential ProxSDCA inner loop exploits to refresh only the
/// touched coordinates of `w = ∇g*(ṽ)` after a sparse dual update —
/// hence the per-coordinate [`Regularizer::grad_conj_at`].
pub trait Regularizer: Send + Sync + std::fmt::Debug {
    /// `g(w)`.
    fn value(&self, w: &[f64]) -> f64;

    /// `g*(v)`.
    fn conj(&self, v: &[f64]) -> f64;

    /// Elementwise `∇g*`: component `j` of the map at `v[j] = vj`.
    fn grad_conj_at(&self, j: usize, vj: f64) -> f64;

    /// `w = ∇g*(v)` written into `w` (the primal-from-dual map, Eq. 3/10).
    fn grad_conj_into(&self, v: &[f64], w: &mut [f64]) {
        debug_assert_eq!(v.len(), w.len());
        for (j, (wj, &vj)) in w.iter_mut().zip(v).enumerate() {
            *wj = self.grad_conj_at(j, vj);
        }
    }

    /// Allocating convenience wrapper.
    fn grad_conj(&self, v: &[f64]) -> Vec<f64> {
        let mut w = vec![0.0; v.len()];
        self.grad_conj_into(v, &mut w);
        w
    }

    /// Strong convexity modulus w.r.t. ‖·‖₂ (the theorems assume 1).
    fn strong_convexity(&self) -> f64 {
        1.0
    }

    /// Wire-serializable form for the TCP cluster backend's `SetReg`
    /// frame (DESIGN.md §9), if this regularizer can travel. `None`
    /// (the default) makes the TCP coordinator fail fast with a clear
    /// message instead of silently desynchronizing the workers.
    fn wire_spec(&self) -> Option<crate::comm::wire::WireReg> {
        None
    }

    /// Name for bench output.
    fn name(&self) -> &'static str;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::for_each_case;
    use crate::utils::math::{dot, l2_norm_sq};

    /// Conjugate consistency: `g(w) + g*(v) = wᵀv` at `w = ∇g*(v)`
    /// (Fenchel–Young equality), and `≥` elsewhere.
    fn check_conjugate<R: Regularizer>(reg: &R, seed: u64) {
        for_each_case(seed, 100, |g| {
            let d = g.usize_in(1, 10);
            let v = g.vec_f64(d, -3.0, 3.0);
            let w_star = reg.grad_conj(&v);
            let eq = reg.value(&w_star) + reg.conj(&v) - dot(&w_star, &v);
            assert!(eq.abs() < 1e-9, "FY equality violated: {eq}");
            let w_other = g.vec_f64(d, -3.0, 3.0);
            let ineq = reg.value(&w_other) + reg.conj(&v) - dot(&w_other, &v);
            assert!(ineq >= -1e-9, "FY inequality violated: {ineq}");
        });
    }

    /// 1-strong convexity of g ⇒ 1-smoothness of g*:
    /// `g*(b) ≤ g*(a) + ∇g*(a)ᵀ(b−a) + ½‖b−a‖²`.
    fn check_conj_smooth<R: Regularizer>(reg: &R, seed: u64) {
        for_each_case(seed, 100, |g| {
            let d = g.usize_in(1, 8);
            let a = g.vec_f64(d, -3.0, 3.0);
            let b = g.vec_f64(d, -3.0, 3.0);
            let grad_a = reg.grad_conj(&a);
            let diff: Vec<f64> = b.iter().zip(&a).map(|(x, y)| x - y).collect();
            let bound = reg.conj(&a) + dot(&grad_a, &diff) + 0.5 * l2_norm_sq(&diff);
            assert!(
                reg.conj(&b) <= bound + 1e-9,
                "g* not 1-smooth: {} > {bound}",
                reg.conj(&b)
            );
        });
    }

    #[test]
    fn elastic_net_conjugate_laws() {
        check_conjugate(&ElasticNet::new(0.0), 0x91);
        check_conjugate(&ElasticNet::new(0.5), 0x92);
        check_conjugate(&ElasticNet::new(2.0), 0x93);
    }

    #[test]
    fn elastic_net_conjugate_smooth() {
        check_conj_smooth(&ElasticNet::new(0.0), 0x94);
        check_conj_smooth(&ElasticNet::new(1.0), 0x95);
    }
}
