//! The extra convex regularizer `h(w)` and its dual-side interface.
//!
//! §5–§6 of the paper allow an arbitrary convex `h` whose conjugate
//! `h*(Σ_ℓ β_ℓ)` couples the machines; `h = 0` (the experiments' choice)
//! makes `h*` the indicator of `{0}`, i.e. the constraint `Σβ_ℓ = 0`.
//! §6's sparse-group-lasso discussion assigns the group norm
//! `h(w) = λ₁ Σ_G ‖w_G‖₂` to `h` so local updates keep closed form and
//! only the global synchronization (Proposition 4) pays for the group
//! prox — both are implemented here.

/// Extra convex regularizer `h` with the maps the global step needs.
pub trait ExtraReg: Send + Sync + std::fmt::Debug {
    /// `h(w)`.
    fn value(&self, w: &[f64]) -> f64;

    /// `h*(b)` where `b = Σ_ℓ β_ℓ` (often an indicator: 0 or +∞).
    fn conj(&self, b: &[f64]) -> f64;

    /// Proximal map `argmin_w ½‖w − z‖² + scale·h(w)` — the Proposition-4
    /// global synchronization step uses this with `scale = 1/(λn)` after
    /// the elastic-net soft-threshold.
    fn prox(&self, z: &[f64], scale: f64) -> Vec<f64> {
        let mut out = vec![0.0; z.len()];
        self.prox_into(z, scale, &mut out);
        out
    }

    /// [`ExtraReg::prox`] written into a caller-owned buffer — the
    /// allocation-free form the per-round global step uses (the scratch
    /// workspace of DESIGN.md §4).
    fn prox_into(&self, z: &[f64], scale: f64, out: &mut [f64]);

    /// Name for bench output.
    fn name(&self) -> &'static str;
}

/// `h = 0` — the experiments' default; `h*` is the indicator of `{0}`.
#[derive(Clone, Copy, Debug, Default)]
pub struct Zero;

impl ExtraReg for Zero {
    fn value(&self, _w: &[f64]) -> f64 {
        0.0
    }

    fn conj(&self, b: &[f64]) -> f64 {
        // Indicator of {0}; tolerate numerical dust from the allreduce.
        if b.iter().all(|&x| x.abs() < 1e-9) {
            0.0
        } else {
            f64::INFINITY
        }
    }

    fn prox_into(&self, z: &[f64], _scale: f64, out: &mut [f64]) {
        out.copy_from_slice(z);
    }

    fn name(&self) -> &'static str {
        "zero"
    }
}

/// Group lasso `h(w) = weight · Σ_G ‖w_G‖₂` over disjoint index groups.
#[derive(Clone, Debug)]
pub struct GroupLasso {
    groups: Vec<std::ops::Range<usize>>,
    weight: f64,
}

impl GroupLasso {
    /// Build from disjoint, sorted index ranges covering ≤ the dimension.
    pub fn new(groups: Vec<std::ops::Range<usize>>, weight: f64) -> Self {
        assert!(weight >= 0.0);
        for pair in groups.windows(2) {
            assert!(
                pair[0].end <= pair[1].start,
                "groups must be disjoint and sorted"
            );
        }
        GroupLasso { groups, weight }
    }

    /// Contiguous equal-size groups over dimension `d`.
    pub fn contiguous(d: usize, group_size: usize, weight: f64) -> Self {
        assert!(group_size >= 1);
        let groups = (0..d)
            .step_by(group_size)
            .map(|s| s..(s + group_size).min(d))
            .collect();
        GroupLasso::new(groups, weight)
    }

    fn group_norm(w: &[f64], g: &std::ops::Range<usize>) -> f64 {
        w[g.clone()].iter().map(|x| x * x).sum::<f64>().sqrt()
    }
}

impl ExtraReg for GroupLasso {
    fn value(&self, w: &[f64]) -> f64 {
        self.weight
            * self
                .groups
                .iter()
                .map(|g| Self::group_norm(w, g))
                .sum::<f64>()
    }

    fn conj(&self, b: &[f64]) -> f64 {
        // h* = indicator{ ‖b_G‖₂ ≤ weight ∀G } ∪ {b = 0 off-group}.
        let covered: Vec<bool> = {
            let mut c = vec![false; b.len()];
            for g in &self.groups {
                for j in g.clone() {
                    c[j] = true;
                }
            }
            c
        };
        for (j, &bj) in b.iter().enumerate() {
            if !covered[j] && bj.abs() > 1e-9 {
                return f64::INFINITY;
            }
        }
        for g in &self.groups {
            if Self::group_norm(b, g) > self.weight + 1e-9 {
                return f64::INFINITY;
            }
        }
        0.0
    }

    fn prox_into(&self, z: &[f64], scale: f64, out: &mut [f64]) {
        // Group soft-threshold (block shrinkage): w_G = max(0, 1 − c/‖z_G‖)·z_G.
        let c = scale * self.weight;
        out.copy_from_slice(z);
        for g in &self.groups {
            let norm = Self::group_norm(z, g);
            let shrink = if norm > c { 1.0 - c / norm } else { 0.0 };
            for j in g.clone() {
                out[j] = shrink * z[j];
            }
        }
    }

    fn name(&self) -> &'static str {
        "group_lasso"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::for_each_case;

    #[test]
    fn zero_is_trivial() {
        let h = Zero;
        assert_eq!(h.value(&[1.0, 2.0]), 0.0);
        assert_eq!(h.conj(&[0.0, 0.0]), 0.0);
        assert!(h.conj(&[0.1, 0.0]).is_infinite());
        assert_eq!(h.prox(&[1.0, -2.0], 0.5), vec![1.0, -2.0]);
    }

    #[test]
    fn group_lasso_value() {
        let h = GroupLasso::contiguous(4, 2, 2.0);
        // groups {0,1}, {2,3}: 2·(5 + 13^.5)
        let w = [3.0, 4.0, 2.0, 3.0];
        assert!((h.value(&w) - 2.0 * (5.0 + 13f64.sqrt())).abs() < 1e-12);
    }

    #[test]
    fn prox_kills_small_groups_keeps_direction() {
        let h = GroupLasso::contiguous(4, 2, 1.0);
        let z = [3.0, 4.0, 0.1, 0.1];
        let w = h.prox(&z, 1.0);
        // group 1: ‖z‖=5 > 1 ⇒ scaled by 4/5
        assert!((w[0] - 2.4).abs() < 1e-12);
        assert!((w[1] - 3.2).abs() < 1e-12);
        // group 2: ‖z‖ < 1 ⇒ zeroed
        assert_eq!(&w[2..], &[0.0, 0.0]);
    }

    #[test]
    fn prox_matches_grid_search_1d_groups() {
        // With singleton groups the prox must equal scalar soft-threshold.
        let h = GroupLasso::contiguous(1, 1, 0.7);
        for_each_case(0xB1, 50, |g| {
            let z = g.f64_in(-3.0, 3.0);
            let scale = g.f64_log_in(0.1, 10.0);
            let got = h.prox(&[z], scale)[0];
            let want = crate::utils::math::soft_threshold_scalar(z, 0.7 * scale);
            assert!((got - want).abs() < 1e-12);
        });
    }

    #[test]
    fn prox_is_optimal_by_perturbation() {
        let h = GroupLasso::contiguous(6, 3, 1.5);
        for_each_case(0xB2, 40, |g| {
            let z = g.vec_f64(6, -2.0, 2.0);
            let scale = g.f64_log_in(0.05, 5.0);
            let w = h.prox(&z, scale);
            let obj = |w: &[f64]| {
                0.5 * w
                    .iter()
                    .zip(&z)
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum::<f64>()
                    + scale * h.value(w)
            };
            let base = obj(&w);
            // Random perturbations must not improve the objective.
            for _ in 0..20 {
                let pert: Vec<f64> = w
                    .iter()
                    .map(|&x| x + g.f64_in(-0.05, 0.05))
                    .collect();
                assert!(obj(&pert) >= base - 1e-9);
            }
        });
    }

    #[test]
    fn conj_indicator() {
        let h = GroupLasso::contiguous(2, 2, 1.0);
        assert_eq!(h.conj(&[0.6, 0.6]), 0.0); // ‖b‖ ≈ 0.85 ≤ 1
        assert!(h.conj(&[1.0, 1.0]).is_infinite()); // ‖b‖ ≈ 1.41 > 1
    }

    #[test]
    #[should_panic]
    fn rejects_overlapping_groups() {
        GroupLasso::new(vec![0..3, 2..5], 1.0);
    }
}
