//! Figures 10 & 11: Logistic-regression scalability — same protocol as
//! Figures 8/9 (fixed mini-batch size, m ∈ {4..32}) with the logistic
//! loss.

use dadm::config::Method;
use dadm::coordinator::NuChoice;
use dadm::experiments::*;
use dadm::loss::Logistic;
use dadm::metrics::bench::BenchTable;

fn main() {
    let datasets = bench_datasets();
    let mut table = BenchTable::new(
        "fig10_11_scalability_lr",
        &[
            "dataset", "lambda", "machines", "sp", "method", "comms_to_1e-3",
            "time_to_1e-3_s", "comm_time_s",
        ],
    );
    let max = 100.0;
    let grid = [(4usize, 0.04f64), (8, 0.08), (16, 0.16), (32, 0.32)];
    for data in datasets.iter().take(2) {
        for (li, &lambda) in lambda_grid(data.n()).iter().enumerate().take(2) {
            for &(m, sp) in &grid {
                for (name, method) in [("CoCoA+", Method::Dadm), ("Acc-DADM", Method::AccDadm)] {
                    let cell =
                        run_cell(data, Logistic, method, lambda, sp, m, NuChoice::Zero, max);
                    table.row(&[
                        data.name.clone(),
                        lambda_label(li).into(),
                        m.to_string(),
                        format!("{sp}"),
                        name.into(),
                        fmt_or_max(cell.comms_to_target, (max / sp) as usize),
                        fmt_secs_opt(cell.time_to_target),
                        format!("{:.4}", cell.comm_secs),
                    ]);
                }
            }
        }
    }
    table.finish();
    println!("\nShape check (paper Figs 10-11): same as the SVM panels — Acc-DADM");
    println!("scales with m, CoCoA+ saturates/caps at small λ.");
}
