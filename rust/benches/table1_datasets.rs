//! Table 1: dataset statistics.
//!
//! Regenerates the paper's dataset table for the synthetic analogues,
//! printing (n, d, sparsity) next to the paper's original values so the
//! profile match is auditable. Scale via `DADM_BENCH_SCALE` (default
//! keeps every bench laptop-fast).

use dadm::data::synthetic::paper_suite;
use dadm::metrics::bench::BenchTable;

fn main() {
    let scale: f64 = std::env::var("DADM_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(5e-4);
    let paper = [
        ("covtype", 581_012usize, 54usize, 22.12),
        ("rcv1", 677_399, 47_236, 0.16),
        ("HIGGS", 11_000_000, 28, 92.11),
        ("kdd2010", 19_264_097, 29_890_095, 9.8e-5),
    ];
    let mut table = BenchTable::new(
        "table1_datasets",
        &[
            "dataset",
            "n",
            "d",
            "sparsity%",
            "paper_n",
            "paper_d",
            "paper_sparsity%",
            "R",
        ],
    );
    for (spec, (pname, pn, pd, psp)) in paper_suite(scale).iter().zip(paper) {
        let data = spec.generate();
        table.row(&[
            data.name.clone(),
            data.n().to_string(),
            data.dim().to_string(),
            format!("{:.3}", data.density() * 100.0),
            pn.to_string(),
            pd.to_string(),
            format!("{psp}"),
            format!("{:.3}", data.max_row_norm_sq()),
        ]);
        let _ = pname;
    }
    table.finish();
    println!(
        "\nNote: d for rcv1/kdd2010 analogues is reduced with density scaled to keep\n\
         nnz/row realistic; rows are unit-normalized so R = 1 (see DESIGN.md §3)."
    );
}
