//! Figures 8 & 9: SVM scalability — communications (Fig 8) and modeled
//! time with the communication share (Fig 9) needed to reach the 1e-3
//! normalized gap, versus the number of machines, at a **fixed
//! mini-batch size** (sp grows with m exactly as §10 prescribes:
//! sp ∈ {0.04, 0.08, 0.16, 0.32} as m ∈ {4, 8, 16, 32}).
//!
//! Paper shape: Acc-DADM's comms stay flat-or-falling with m while
//! CoCoA+ degrades (and caps out entirely at small λ).

use dadm::config::Method;
use dadm::coordinator::NuChoice;
use dadm::experiments::*;
use dadm::loss::SmoothHinge;
use dadm::metrics::bench::BenchTable;

fn main() {
    let datasets = bench_datasets();
    let mut table = BenchTable::new(
        "fig8_9_scalability_svm",
        &[
            "dataset", "lambda", "machines", "sp", "method", "comms_to_1e-3",
            "time_to_1e-3_s", "comm_time_s",
        ],
    );
    let max = 100.0;
    let grid = [(4usize, 0.04f64), (8, 0.08), (16, 0.16), (32, 0.32)];
    for data in datasets.iter().take(2) {
        for (li, &lambda) in lambda_grid(data.n()).iter().enumerate().take(2) {
            for &(m, sp) in &grid {
                for (name, method) in [("CoCoA+", Method::Dadm), ("Acc-DADM", Method::AccDadm)] {
                    let cell = run_cell(
                        data,
                        SmoothHinge::default(),
                        method,
                        lambda,
                        sp,
                        m,
                        NuChoice::Zero,
                        max,
                    );
                    table.row(&[
                        data.name.clone(),
                        lambda_label(li).into(),
                        m.to_string(),
                        format!("{sp}"),
                        name.into(),
                        fmt_or_max(cell.comms_to_target, (max / sp) as usize),
                        fmt_secs_opt(cell.time_to_target),
                        format!("{:.4}", cell.comm_secs),
                    ]);
                }
            }
        }
    }
    table.finish();
    println!("\nShape check (paper Figs 8-9): at fixed mini-batch size, Acc-DADM's");
    println!("comms-to-target do not grow with m; CoCoA+ hits the cap at λ = 1e-7.");
}
