//! Figures 12 & 13: non-smooth hinge loss — normalized duality gap vs
//! communications (Fig 12) and modeled time (Fig 13).
//!
//! CoCoA+ runs the plain hinge (Theorem-7 Lipschitz regime); Acc-DADM
//! runs the Nesterov-smoothed hinge (§8.2 / Corollary 13; practical γ).
//! Paper shape: acceleration carries over — Acc-DADM converges
//! significantly faster, especially at small λ.

use dadm::config::Method;
use dadm::coordinator::NuChoice;
use dadm::experiments::*;
use dadm::loss::{Hinge, SmoothHinge};
use dadm::metrics::bench::BenchTable;

fn main() {
    let datasets = bench_datasets();
    let mut table = BenchTable::new(
        "fig12_13_hinge",
        &[
            "dataset", "lambda", "sp", "method", "comms_to_1e-3", "time_to_1e-3_s",
            "final_gap",
        ],
    );
    let max = 100.0;
    for data in datasets.iter().take(2) {
        let m = 8;
        for (li, &lambda) in lambda_grid(data.n()).iter().enumerate() {
            for &sp in &SP_GRID {
                // CoCoA+ on the plain (non-smooth) hinge.
                let cell = run_cell(data, Hinge, Method::Dadm, lambda, sp, m, NuChoice::Zero, max);
                table.row(&[
                    data.name.clone(),
                    lambda_label(li).into(),
                    format!("{sp}"),
                    "CoCoA+".into(),
                    fmt_or_max(cell.comms_to_target, (max / sp) as usize),
                    fmt_secs_opt(cell.time_to_target),
                    format!("{:.3e}", cell.final_gap),
                ]);
                // Acc-DADM on the Nesterov-smoothed hinge. Corollary 13's
                // exact transfer needs γ = ε/L², but at this reduced scale
                // that condition number is unreachable under the 100-pass
                // cap (κ = mR/(γn) ≈ 2.75 here vs 0.014 at the paper's n);
                // we use the practical γ = 0.1 and measure the smoothed
                // objective's gap, as §8.2 prescribes ("we minimize the
                // smoothed objective"). See EXPERIMENTS.md §F12-13.
                let cell = run_cell(
                    data,
                    SmoothHinge::new(0.1),
                    Method::AccDadm,
                    lambda,
                    sp,
                    m,
                    NuChoice::Zero,
                    max,
                );
                table.row(&[
                    data.name.clone(),
                    lambda_label(li).into(),
                    format!("{sp}"),
                    "Acc-DADM".into(),
                    fmt_or_max(cell.comms_to_target, (max / sp) as usize),
                    fmt_secs_opt(cell.time_to_target),
                    format!("{:.3e}", cell.final_gap),
                ]);
            }
        }
    }
    table.finish();
    println!("\nShape check (paper Figs 12-13): smoothing + acceleration beats the");
    println!("Lipschitz-rate CoCoA+ on the plain hinge, most visibly at small λ.");
}
