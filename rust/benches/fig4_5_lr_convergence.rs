//! Figures 4 & 5: Logistic Regression — normalized duality gap vs
//! communications (Fig 4) and vs modeled time (Fig 5), CoCoA+ vs
//! Acc-DADM, dataset analogues × λ grid × sp grid.
//!
//! Same expected shape as the SVM panels: Acc-DADM dominates, with the
//! margin growing as λ shrinks.

use dadm::config::Method;
use dadm::coordinator::NuChoice;
use dadm::experiments::*;
use dadm::loss::Logistic;
use dadm::metrics::bench::BenchTable;

fn main() {
    let datasets = bench_datasets();
    let mut table = BenchTable::new(
        "fig4_5_lr_convergence",
        &[
            "dataset", "lambda", "sp", "method", "comms_to_1e-3", "time_to_1e-3_s",
            "comm_time_s", "final_gap",
        ],
    );
    let max = 100.0;
    for data in &datasets {
        let m = if data.n() > 8_000 { 20 } else { 8 };
        for (li, &lambda) in lambda_grid(data.n()).iter().enumerate() {
            for &sp in &SP_GRID {
                for (name, method) in [("CoCoA+", Method::Dadm), ("Acc-DADM", Method::AccDadm)] {
                    let cell =
                        run_cell(data, Logistic, method, lambda, sp, m, NuChoice::Zero, max);
                    table.row(&[
                        data.name.clone(),
                        lambda_label(li).into(),
                        format!("{sp}"),
                        name.into(),
                        fmt_or_max(cell.comms_to_target, (max / sp) as usize),
                        fmt_secs_opt(cell.time_to_target),
                        format!("{:.4}", cell.comm_secs),
                        format!("{:.3e}", cell.final_gap),
                    ]);
                }
            }
        }
    }
    table.finish();
    println!("\nShape check (paper Figs 4-5): Acc-DADM ≤ CoCoA+ in comms on every cell.");
}
