//! Ablations for the design choices DESIGN.md calls out.
//!
//! * **Local solver** (Remark 10): the analyzed conservative Theorem-6
//!   step vs the practical sequential ProxSDCA — the paper claims actual
//!   performance is "significantly better than what is indicated by the
//!   bounds when the local duals are better optimized".
//! * **κ choice** (Remark 12): the default `κ = mR/(γn) − λ` vs
//!   under-/over-regularized prox weights.

use dadm::comm::CostModel;
use dadm::coordinator::{AccDadmOptions, DadmOptions, Problem};
use dadm::data::synthetic::SyntheticSpec;
use dadm::data::Partition;
use dadm::loss::SmoothHinge;
use dadm::metrics::bench::BenchTable;
use dadm::reg::ElasticNet;
use dadm::solver::{ProxSdca, TheoremStep};

fn main() {
    let data = SyntheticSpec::covtype(0.005).generate();
    let part = Partition::balanced(data.n(), 8, 7);
    let lambda = 0.07 / data.n() as f64; // the mid grid point (λn = 0.07)
    let mu = 1e-5;
    let eps = 1e-3;
    let opts = DadmOptions {
        sp: 0.2,
        cost: CostModel::free(),
        gap_every: 3,
        ..Default::default()
    };
    let max_rounds = 500;

    let mut table = BenchTable::new(
        "ablation",
        &["ablation", "variant", "comms_to_1e-3", "final_gap"],
    );

    // --- Local solver ablation (plain DADM) ---
    {
        let mut dadm = Problem::new(&data, &part)
            .loss(SmoothHinge::default())
            .reg(ElasticNet::new(mu / lambda))
            .lambda(lambda)
            .build_dadm(ProxSdca, opts.clone());
        let r = dadm.solve(eps, max_rounds);
        table.row(&[
            "local_solver".into(),
            "prox_sdca (practical)".into(),
            r.trace
                .rounds_to_gap(eps)
                .map(|c| c.to_string())
                .unwrap_or(format!(">{max_rounds}")),
            format!("{:.3e}", r.normalized_gap()),
        ]);
        let mut dadm = Problem::new(&data, &part)
            .loss(SmoothHinge::default())
            .reg(ElasticNet::new(mu / lambda))
            .lambda(lambda)
            .build_dadm(
                TheoremStep {
                    radius: data.max_row_norm_sq(),
                },
                opts.clone(),
            );
        let r = dadm.solve(eps, max_rounds);
        table.row(&[
            "local_solver".into(),
            "theorem-6 (analyzed)".into(),
            r.trace
                .rounds_to_gap(eps)
                .map(|c| c.to_string())
                .unwrap_or(format!(">{max_rounds}")),
            format!("{:.3e}", r.normalized_gap()),
        ]);
    }

    // --- κ ablation (Acc-DADM) ---
    let kappa_star = part.machines() as f64 * data.max_row_norm_sq() / data.n() as f64 - lambda;
    for (name, kappa) in [
        ("κ*/16 (under)", kappa_star / 16.0),
        ("κ* = mR/(γn)−λ", kappa_star),
        ("16κ* (over)", kappa_star * 16.0),
        ("κ = 0 (≡ DADM)", 0.0),
    ] {
        let mut acc = Problem::new(&data, &part)
            .loss(SmoothHinge::default())
            .lambda(lambda)
            .l1(mu)
            .build_acc_dadm(
                ProxSdca,
                AccDadmOptions {
                    kappa: Some(kappa.max(0.0)),
                    dadm: opts.clone(),
                    ..Default::default()
                },
            );
        let r = acc.solve(eps, max_rounds);
        table.row(&[
            "kappa".into(),
            name.into(),
            r.trace
                .rounds_to_gap(eps)
                .map(|c| c.to_string())
                .unwrap_or(format!(">{max_rounds}")),
            format!("{:.3e}", r.normalized_gap()),
        ]);
    }

    table.finish();
    println!("\nExpected: prox_sdca ≪ theorem-6 in comms (Remark 10); κ* near-optimal");
    println!("with degradation both under- and over-regularized (Remark 12).");
}
