//! Figures 6 & 7: OWL-QN vs CoCoA+ vs Acc-DADM on L2-L1 logistic
//! regression, sp = 1.0 (one communication per pass), normalized primal
//! objective vs passes (Fig 6) and vs modeled time (Fig 7).
//!
//! Paper shape: the dual methods reach low objective in far fewer passes
//! than the batch quasi-Newton baseline, and Acc-DADM keeps its edge as
//! λ shrinks.

use dadm::comm::{Cluster, CostModel};
use dadm::config::Method;
use dadm::coordinator::{NuChoice, Problem};
use dadm::data::Partition;
use dadm::experiments::*;
use dadm::loss::Logistic;
use dadm::metrics::bench::BenchTable;

fn main() {
    let datasets = bench_datasets();
    let mut table = BenchTable::new(
        "fig6_7_owlqn",
        &[
            "dataset", "lambda", "method", "passes", "final_norm_primal", "modeled_secs",
        ],
    );
    let max_passes = 100usize;
    for data in datasets.iter().take(2) {
        // covtype + rcv1 analogues (the paper's medium datasets, m = 8)
        let m = 8;
        for (li, &lambda) in lambda_grid(data.n()).iter().enumerate() {
            // OWL-QN baseline.
            let part = Partition::balanced(data.n(), m, 7);
            let ow = Problem::new(data, &part)
                .loss(Logistic)
                .lambda(lambda)
                .l1(MU)
                .solve_owlqn(max_passes, Cluster::Serial, CostModel::default(), 1);
            table.row(&[
                data.name.clone(),
                lambda_label(li).into(),
                "OWL-QN".into(),
                ow.passes.to_string(),
                format!("{:.6e}", ow.objective),
                format!("{:.4}", ow.compute_secs + ow.comm_secs),
            ]);
            // Dual methods at sp = 1.0.
            for (name, method) in [("CoCoA+", Method::Dadm), ("Acc-DADM", Method::AccDadm)] {
                let cell = run_cell(
                    data,
                    Logistic,
                    method,
                    lambda,
                    1.0,
                    m,
                    NuChoice::Zero,
                    max_passes as f64,
                );
                let norm_primal = cell
                    .report
                    .trace
                    .last()
                    .map(|r| r.primal / data.n() as f64)
                    .unwrap_or(f64::NAN);
                table.row(&[
                    data.name.clone(),
                    lambda_label(li).into(),
                    name.into(),
                    format!("{:.0}", cell.report.passes),
                    format!("{norm_primal:.6e}"),
                    format!(
                        "{:.4}",
                        cell.report.trace.last().map(|r| r.modeled_secs()).unwrap_or(0.0)
                    ),
                ]);
            }
        }
    }
    table.finish();
    println!("\nShape check (paper Figs 6-7): dual methods hit lower objective in fewer");
    println!("passes than OWL-QN; Acc-DADM converges fastest at small λ.");
}
