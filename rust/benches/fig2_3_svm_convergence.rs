//! Figures 2 & 3: SVM (smooth hinge) — normalized duality gap vs number
//! of communications (Fig 2) and vs modeled time (Fig 3), CoCoA+ vs
//! Acc-DADM, all four dataset analogues × λ grid × sp grid.
//!
//! Paper shape to reproduce: Acc-DADM ≤ CoCoA+ everywhere; the advantage
//! explodes as λ shrinks (CoCoA+ hits the 100-pass cap at λ ~ 1e-8 while
//! Acc-DADM still converges); larger sp ⇒ fewer communications.

use dadm::config::Method;
use dadm::coordinator::NuChoice;
use dadm::experiments::*;
use dadm::loss::SmoothHinge;
use dadm::metrics::bench::BenchTable;
use dadm::metrics::plot::{render, series_from_trace, PlotSpec};

fn main() {
    let datasets = bench_datasets();
    let mut panel: Vec<dadm::metrics::plot::Series> = Vec::new();
    let mut table = BenchTable::new(
        "fig2_3_svm_convergence",
        &[
            "dataset", "lambda", "sp", "method", "comms_to_1e-3", "time_to_1e-3_s",
            "comm_time_s", "final_gap",
        ],
    );
    let max = 100.0;
    for data in &datasets {
        let m = if data.n() > 8_000 { 20 } else { 8 }; // §10 machine counts
        for (li, &lambda) in lambda_grid(data.n()).iter().enumerate() {
            for &sp in &SP_GRID {
                for (name, method) in [("CoCoA+", Method::Dadm), ("Acc-DADM", Method::AccDadm)] {
                    let cell = run_cell(
                        data,
                        SmoothHinge::default(),
                        method,
                        lambda,
                        sp,
                        m,
                        NuChoice::Zero,
                        max,
                    );
                    // One representative curve panel (the paper's middle
                    // column: λ̂ = 1e-7, sp = 0.2, covtype analogue).
                    if data.name == "synth-covtype" && li == 1 && sp == 0.20 {
                        panel.push(series_from_trace(name, &cell.report.trace));
                    }
                    table.row(&[
                        data.name.clone(),
                        lambda_label(li).into(),
                        format!("{sp}"),
                        name.into(),
                        fmt_or_max(cell.comms_to_target, (max / sp) as usize),
                        fmt_secs_opt(cell.time_to_target),
                        format!("{:.4}", cell.comm_secs),
                        format!("{:.3e}", cell.final_gap),
                    ]);
                }
            }
        }
    }
    table.finish();
    println!(
        "\nFig-2 curve panel (synth-covtype, λ̂ = 1e-7, sp = 0.2):\n{}",
        render(&PlotSpec::default(), &panel)
    );
    println!("\nShape check (paper Figs 2-3): Acc-DADM needs no more comms than CoCoA+");
    println!("on every cell, and CoCoA+ caps out (>max) at the smallest λ.");
}
