//! Figure 1: Acc-DADM with the theory momentum ν = (1−η)/(1+η) vs the
//! practical ν = 0, SVM (smooth hinge), μ = 1e-5, λ and sp grids.
//!
//! Paper shape to reproduce: both variants accelerate; the theory ν
//! converges with rippling, ν = 0 is smoother — and both dominate plain
//! DADM/CoCoA+ at small λ.

use dadm::config::Method;
use dadm::coordinator::NuChoice;
use dadm::experiments::*;
use dadm::loss::SmoothHinge;
use dadm::metrics::bench::BenchTable;

fn main() {
    let datasets = bench_datasets();
    let data = &datasets[0]; // covtype analogue, as in the paper's panel 1
    let mut table = BenchTable::new(
        "fig1_momentum",
        &["dataset", "lambda", "sp", "variant", "comms_to_1e-3", "final_gap"],
    );
    let max = 100.0;
    for (li, &lambda) in lambda_grid(data.n()).iter().enumerate() {
        for &sp in &SP_GRID {
            for (name, nu) in [
                ("Acc-DADM-theo", NuChoice::Theory),
                ("Acc-DADM-0", NuChoice::Zero),
            ] {
                let cell = run_cell(
                    data,
                    SmoothHinge::default(),
                    Method::AccDadm,
                    lambda,
                    sp,
                    8,
                    nu,
                    max,
                );
                table.row(&[
                    data.name.clone(),
                    lambda_label(li).into(),
                    format!("{sp}"),
                    name.into(),
                    fmt_or_max(cell.comms_to_target, (max / sp) as usize),
                    format!("{:.3e}", cell.final_gap),
                ]);
            }
        }
    }
    table.finish();
    println!("\nShape check (paper Fig 1): both ν choices reach the target; the");
    println!("theory ν may ripple (slightly more comms on some cells), ν = 0 is smooth.");
}
