//! §Perf micro-benchmarks: the L3 hot paths.
//!
//! * ProxSDCA epoch throughput (coordinate updates/s, dense + sparse) —
//!   the innermost solve loop;
//! * Theorem-step batched update throughput;
//! * tree allreduce bandwidth (dense + sparse Δv messages);
//! * full DADM rounds on the sparse-delta pipeline (dense vs sparse
//!   workloads, per-round message sizes);
//! * full DADM rounds over the loopback TCP transport (real sockets,
//!   per-round wire bytes);
//! * PJRT artifact execute latency (when `artifacts/` exists).
//!
//! Problem sizes scale with `DADM_BENCH_SCALE` (a float, or `smoke` for
//! the CI bench-smoke job); results land in
//! `target/bench_out/BENCH_perf_hotpath.json` and feed EXPERIMENTS.md
//! §Perf (before/after iteration log).

use dadm::comm::sparse::{tree_allreduce_delta, Delta, SparseDelta};
use dadm::comm::CostModel;
use dadm::coordinator::{Dadm, DadmOptions, Problem};
use dadm::data::synthetic::SyntheticSpec;
use dadm::data::{Dataset, Partition};
use dadm::experiments::{bench_scale, scaled_bench_n};
use dadm::loss::{Loss, SmoothHinge};
use dadm::metrics::bench::{fmt_secs, time_it, BenchTable};
use dadm::reg::{ElasticNet, ExtraReg, Regularizer, Zero};
use dadm::solver::{LocalSolver, ProxSdca, TheoremStep, WorkerState};
use dadm::utils::Rng;

/// Positional convenience over the [`Problem`] builder — the only
/// construction path — for this file's repetitive setups.
#[allow(clippy::too_many_arguments)]
fn build_dadm<L, R, H, S>(
    data: &Dataset,
    part: &Partition,
    loss: L,
    reg: R,
    h: H,
    lambda: f64,
    solver: S,
    opts: DadmOptions,
) -> Dadm<L, R, H, S>
where
    L: Loss,
    R: Regularizer,
    H: ExtraReg,
    S: LocalSolver,
{
    Problem::new(data, part)
        .loss(loss)
        .reg(reg)
        .extra_reg(h)
        .lambda(lambda)
        .build_dadm(solver, opts)
}

fn main() {
    let mut table = BenchTable::new(
        "perf_hotpath",
        &["bench", "config", "median", "throughput"],
    );
    table.meta("scale", format!("{}", bench_scale()));

    // --- ProxSDCA epoch throughput ---
    for (name, density, d) in [("dense", 1.0, 64), ("sparse", 0.02, 2048)] {
        let n = scaled_bench_n(20_000);
        let data = SyntheticSpec {
            name: format!("perf-{name}"),
            n,
            d,
            density,
            signal_density: 0.2,
            noise: 0.1,
            seed: 1,
        }
        .generate();
        let part = Partition::balanced(n, 1, 1);
        let mut ws = WorkerState::from_partition(&data, &part, 0);
        let loss = SmoothHinge::default();
        let reg = ElasticNet::new(0.1);
        let lambda_n_l = 1e-4 * n as f64;
        let batch: Vec<usize> = (0..n).collect();
        let mut rng = Rng::new(2);
        let t = time_it(1, 5, || {
            let dv = ProxSdca
                .local_step(&mut ws, &batch, &loss, &reg, lambda_n_l, &mut rng)
                .into_dense();
            ws.apply_global(&dv, &reg);
        });
        let coords_per_sec = n as f64 / t.median;
        let nnz_per_sec = data.x.nnz() as f64 / t.median;
        table.row(&[
            "prox_sdca_epoch".into(),
            format!("{name} n={n} d={d}"),
            fmt_secs(t.median),
            format!("{:.2}M coord/s, {:.1}M nnz/s", coords_per_sec / 1e6, nnz_per_sec / 1e6),
        ]);
    }

    // --- ProxSDCA mini-batch regime (sp ≪ 1: many small local steps) ---
    {
        let n = scaled_bench_n(20_000);
        let d = 2048;
        let data = SyntheticSpec {
            name: "perf-mini".into(),
            n,
            d,
            density: 0.02,
            signal_density: 0.2,
            noise: 0.1,
            seed: 9,
        }
        .generate();
        let part = Partition::balanced(n, 1, 1);
        let mut ws = WorkerState::from_partition(&data, &part, 0);
        let loss = SmoothHinge::default();
        let reg = ElasticNet::new(0.1);
        let lambda_n_l = 1e-4 * n as f64;
        let m_batch = 64usize;
        let mut rng = Rng::new(7);
        let calls = 100;
        let t = time_it(1, 5, || {
            for _ in 0..calls {
                let batch = rng.sample_indices(n, m_batch);
                let _ = ProxSdca.local_step(&mut ws, &batch, &loss, &reg, lambda_n_l, &mut rng);
            }
        });
        table.row(&[
            "prox_sdca_minibatch".into(),
            format!("M={m_batch} d={d} x{calls} calls"),
            fmt_secs(t.median / calls as f64),
            format!("{:.2}M coord/s", (calls * m_batch) as f64 / t.median / 1e6),
        ]);
    }

    // --- Theorem batched step ---
    {
        let n = scaled_bench_n(20_000);
        let data = SyntheticSpec {
            name: "perf-thm".into(),
            n,
            d: 256,
            density: 0.1,
            signal_density: 0.2,
            noise: 0.1,
            seed: 3,
        }
        .generate();
        let part = Partition::balanced(n, 1, 1);
        let mut ws = WorkerState::from_partition(&data, &part, 0);
        let loss = SmoothHinge::default();
        let reg = ElasticNet::new(0.1);
        let batch: Vec<usize> = (0..n).collect();
        let mut rng = Rng::new(4);
        let step = TheoremStep { radius: 1.0 };
        let t = time_it(1, 5, || {
            let dv = step
                .local_step(&mut ws, &batch, &loss, &reg, 2.0, &mut rng)
                .into_dense();
            ws.apply_global(&dv, &reg);
        });
        table.row(&[
            "theorem_step_epoch".into(),
            format!("n={n} d=256 dens=0.1"),
            fmt_secs(t.median),
            format!("{:.2}M coord/s", n as f64 / t.median / 1e6),
        ]);
    }

    // --- Allreduce ---
    for m in [8usize, 32] {
        let d = 1 << 16;
        let contribs: Vec<Vec<f64>> = (0..m).map(|l| vec![l as f64; d]).collect();
        let weights = vec![1.0 / m as f64; m];
        let t = time_it(2, 10, || {
            let out = dadm::comm::allreduce::tree_allreduce(&contribs, &weights);
            assert_eq!(out.len(), d);
        });
        table.row(&[
            "tree_allreduce".into(),
            format!("m={m} d={d}"),
            fmt_secs(t.median),
            format!("{:.2} GB/s", (m * d * 8) as f64 / t.median / 1e9),
        ]);
    }

    // --- Sparse allreduce (rcv1-style Δv support ≪ d) ---
    {
        let (m, d, nnz) = (32usize, 1 << 16, 512usize);
        let mut rng = Rng::new(12);
        let contribs: Vec<SparseDelta> = (0..m)
            .map(|_| {
                let mut idx: Vec<u32> = rng
                    .sample_indices(d, nnz)
                    .into_iter()
                    .map(|j| j as u32)
                    .collect();
                idx.sort_unstable();
                let val: Vec<f64> = (0..nnz).map(|_| rng.normal()).collect();
                SparseDelta { dim: d, idx, val }
            })
            .collect();
        let weights = vec![1.0 / m as f64; m];
        // The reduce consumes its messages, so pre-build one set per
        // run to keep clone/alloc cost out of the measured figure.
        let (warmup, runs) = (2, 10);
        let mut prepared: Vec<Vec<Delta>> = (0..warmup + runs)
            .map(|_| contribs.iter().map(|s| Delta::Sparse(s.clone())).collect())
            .collect();
        let t = time_it(warmup, runs, || {
            let messages = prepared.pop().expect("one prepared set per run");
            let (out, _max_elems) = tree_allreduce_delta(messages, &weights);
            assert_eq!(out.dim(), d);
        });
        table.row(&[
            "tree_allreduce_sparse".into(),
            format!("m={m} d={d} nnz={nnz}"),
            fmt_secs(t.median),
            format!("{:.1}M nnz/s", (m * nnz) as f64 / t.median / 1e6),
        ]);
    }

    // --- Full DADM round on the sparse-delta pipeline ---
    // Dense workload: epoch-style batches emit dense messages — the
    // sparse pipeline must not regress this path. Sparse workload:
    // mini-batches on rcv1-like data emit small sparse messages instead
    // of per-worker dense length-d vectors (per-round allocations drop
    // from m·d to m·nnz).
    // The sparse row sits well inside the sparse regime (batch·avg_nnz
    // ≈ d/5, touched support ≪ the 2·d/3 densify cutoff), so the bench
    // actually measures sparse-message rounds rather than the threshold.
    for (name, density, d, sp) in [
        ("dense", 1.0, 64usize, 1.0),
        ("sparse", 0.01, 2048, 0.02),
    ] {
        let n = scaled_bench_n(8_000);
        let machines = 8;
        let data = SyntheticSpec {
            name: format!("round-{name}"),
            n,
            d,
            density,
            signal_density: 0.2,
            noise: 0.1,
            seed: 13,
        }
        .generate();
        let part = Partition::balanced(n, machines, 13);
        let mut dadm = build_dadm(
            &data,
            &part,
            SmoothHinge::default(),
            ElasticNet::new(0.1),
            Zero,
            1e-4,
            ProxSdca,
            DadmOptions {
                sp,
                cost: CostModel::free(),
                sparse_comm: true,
                ..Default::default()
            },
        );
        dadm.resync();
        let t = time_it(1, 5, || {
            dadm.round();
        });
        // One representative worker message, for the size column.
        let mut ws = WorkerState::from_partition(&data, &part, 0);
        let mut rng = Rng::new(14);
        let batch_len = ((sp * ws.n_l() as f64).ceil() as usize).clamp(1, ws.n_l());
        let batch = rng.sample_indices(ws.n_l(), batch_len);
        let reg = ElasticNet::new(0.1);
        let msg = ProxSdca.local_step(
            &mut ws,
            &batch,
            &SmoothHinge::default(),
            &reg,
            1e-4 * ws.n_l() as f64,
            &mut rng,
        );
        table.row(&[
            "dadm_round_sparse_delta".into(),
            format!("{name} n={n} d={d} m={machines} sp={sp}"),
            fmt_secs(t.median),
            format!("Δv msg {} / dense {} elems", msg.message_elems(), d),
        ]);
    }

    // --- Full DADM round over the loopback TCP transport ---
    // Same round as above but with every machine in a thread-hosted
    // worker behind a real socket (the in-process twin of `dadm worker`
    // processes): measures transport overhead per round and reports the
    // actual wire bytes a sparse round moves.
    {
        use dadm::comm::tcp::{serve, synthetic_specs, TcpClusterBuilder, TcpHandle};
        use dadm::comm::wire::{WireLoss, WireSolver};
        use dadm::comm::Cluster;
        let machines = 4usize;
        let n = scaled_bench_n(8_000);
        let (sp, d) = (0.02, 2048usize);
        let spec = SyntheticSpec {
            name: "tcp-round".into(),
            n,
            d,
            density: 0.01,
            signal_density: 0.2,
            noise: 0.1,
            seed: 17,
        };
        let data = spec.generate();
        let part = Partition::balanced(n, machines, 17);
        let builder = TcpClusterBuilder::bind("127.0.0.1:0").expect("bind loopback");
        let addr = builder.local_addr().expect("local addr");
        let workers: Vec<_> = (0..machines)
            .map(|_| {
                std::thread::spawn(move || {
                    let s = std::net::TcpStream::connect(addr).expect("worker connect");
                    serve(s).expect("worker serve");
                })
            })
            .collect();
        let mut cluster = builder.accept(machines).expect("accept workers");
        cluster
            .assign(synthetic_specs(
                &spec,
                machines,
                17,
                0xDAD_A,
                sp,
                WireLoss::SmoothHinge(SmoothHinge::default()),
                WireSolver::ProxSdca,
                1,
            ))
            .expect("assign");
        let handle = TcpHandle::new(cluster);
        let mut dadm = build_dadm(
            &data,
            &part,
            SmoothHinge::default(),
            ElasticNet::new(0.1),
            Zero,
            1e-4,
            ProxSdca,
            DadmOptions {
                sp,
                cluster: Cluster::Tcp(handle.clone()),
                cost: CostModel::free(),
                sparse_comm: true,
                ..Default::default()
            },
        );
        dadm.resync();
        let bytes_before = dadm.wire_bytes();
        let mut rounds_timed = 0u64;
        let t = time_it(2, 8, || {
            dadm.round();
            rounds_timed += 1;
        });
        let per_round = (dadm.wire_bytes() - bytes_before) / rounds_timed.max(1);
        table.row(&[
            "dadm_round_tcp_loopback".into(),
            format!("m={machines} d={d} sp={sp} sparse"),
            fmt_secs(t.median),
            format!("{per_round} B/round on the wire"),
        ]);
        handle.with(|c| c.shutdown());
        drop(dadm);
        drop(handle);
        for w in workers {
            w.join().expect("worker thread");
        }
    }

    // --- Quantized-delta rounds over the loopback TCP transport ---
    // Dense-support workload (the per-round Δv densifies under every
    // codec), so the codec's dense entry width dominates the DeltaReply
    // payload: 8 B/elem exact f64, 4 B f32, 2 B scaled i16 with error
    // feedback (DESIGN.md §13). Reports per-round DeltaReply bytes next
    // to the round time for each codec.
    {
        use dadm::comm::sparse::DeltaCodec;
        use dadm::comm::tcp::{serve, synthetic_specs, TcpClusterBuilder, TcpHandle};
        use dadm::comm::wire::{WireLoss, WireSolver};
        use dadm::comm::Cluster;
        let machines = 4usize;
        let n = scaled_bench_n(4_000);
        let (sp, d) = (0.25, 512usize);
        let spec = SyntheticSpec {
            name: "compressed-round".into(),
            n,
            d,
            density: 0.1,
            signal_density: 0.2,
            noise: 0.1,
            seed: 29,
        };
        let data = spec.generate();
        let part = Partition::balanced(n, machines, 29);
        for codec in [DeltaCodec::F64, DeltaCodec::F32, DeltaCodec::I16] {
            let builder = TcpClusterBuilder::bind("127.0.0.1:0").expect("bind loopback");
            let addr = builder.local_addr().expect("local addr");
            let workers: Vec<_> = (0..machines)
                .map(|_| {
                    std::thread::spawn(move || {
                        let s = std::net::TcpStream::connect(addr).expect("worker connect");
                        serve(s).expect("worker serve");
                    })
                })
                .collect();
            let mut cluster = builder.accept(machines).expect("accept workers");
            cluster
                .assign(synthetic_specs(
                    &spec,
                    machines,
                    29,
                    0xDAD_A,
                    sp,
                    WireLoss::SmoothHinge(SmoothHinge::default()),
                    WireSolver::ProxSdca,
                    1,
                ))
                .expect("assign");
            let handle = TcpHandle::new(cluster);
            let mut dadm = build_dadm(
                &data,
                &part,
                SmoothHinge::default(),
                ElasticNet::new(0.1),
                Zero,
                1e-4,
                ProxSdca,
                DadmOptions {
                    sp,
                    cluster: Cluster::Tcp(handle.clone()),
                    cost: CostModel::free(),
                    sparse_comm: true,
                    compress: codec,
                    ..Default::default()
                },
            );
            dadm.resync();
            let bytes_before = dadm.delta_reply_bytes();
            let mut rounds_timed = 0u64;
            let t = time_it(2, 8, || {
                dadm.round();
                rounds_timed += 1;
            });
            let per_round = (dadm.delta_reply_bytes() - bytes_before) / rounds_timed.max(1);
            table.row(&[
                "dadm_round_compressed".into(),
                format!("m={machines} d={d} sp={sp} codec={}", codec.name()),
                fmt_secs(t.median),
                format!("{per_round} B/round DeltaReply"),
            ]);
            handle.with(|c| c.shutdown());
            drop(dadm);
            drop(handle);
            for w in workers {
                w.join().expect("worker thread");
            }
        }
    }

    // --- Double-buffered rounds over the loopback TCP transport ---
    // Equal work, two schedules: N sequential fused rounds vs N
    // pipelined issue/complete pairs with one round primed in flight
    // (steady-state depth two, DESIGN.md §13). Overlapping round t+1's
    // dispatch with round t's reduce/global step hides the socket
    // turnaround: overlapped should come in at or under sequential.
    {
        use dadm::comm::tcp::{serve, synthetic_specs, TcpClusterBuilder, TcpHandle};
        use dadm::comm::wire::{WireLoss, WireSolver};
        use dadm::comm::Cluster;
        let machines = 4usize;
        let n = scaled_bench_n(8_000);
        let (sp, d) = (0.02, 2048usize);
        let spec = SyntheticSpec {
            name: "overlap-round".into(),
            n,
            d,
            density: 0.01,
            signal_density: 0.2,
            noise: 0.1,
            seed: 31,
        };
        let data = spec.generate();
        let part = Partition::balanced(n, machines, 31);
        for overlapped in [false, true] {
            let builder = TcpClusterBuilder::bind("127.0.0.1:0").expect("bind loopback");
            let addr = builder.local_addr().expect("local addr");
            let workers: Vec<_> = (0..machines)
                .map(|_| {
                    std::thread::spawn(move || {
                        let s = std::net::TcpStream::connect(addr).expect("worker connect");
                        serve(s).expect("worker serve");
                    })
                })
                .collect();
            let mut cluster = builder.accept(machines).expect("accept workers");
            cluster
                .assign(synthetic_specs(
                    &spec,
                    machines,
                    31,
                    0xDAD_A,
                    sp,
                    WireLoss::SmoothHinge(SmoothHinge::default()),
                    WireSolver::ProxSdca,
                    1,
                ))
                .expect("assign");
            let handle = TcpHandle::new(cluster);
            let mut dadm = build_dadm(
                &data,
                &part,
                SmoothHinge::default(),
                ElasticNet::new(0.1),
                Zero,
                1e-4,
                ProxSdca,
                DadmOptions {
                    sp,
                    cluster: Cluster::Tcp(handle.clone()),
                    cost: CostModel::free(),
                    sparse_comm: true,
                    overlap: overlapped,
                    ..Default::default()
                },
            );
            dadm.resync();
            let (mode, t) = if overlapped {
                dadm.round_issue(false, false); // prime the pipeline
                let t = time_it(2, 8, || {
                    dadm.round_issue(false, false);
                    dadm.round_complete();
                });
                dadm.round_complete(); // drain
                ("overlapped", t)
            } else {
                let t = time_it(2, 8, || {
                    dadm.round();
                });
                ("sequential", t)
            };
            table.row(&[
                "dadm_round_overlap".into(),
                format!("m={machines} d={d} sp={sp} {mode}"),
                fmt_secs(t.median),
                format!("barriers={}", dadm.barriers()),
            ]);
            handle.with(|c| c.shutdown());
            drop(dadm);
            drop(handle);
            for w in workers {
                w.join().expect("worker thread");
            }
        }
    }

    // --- Fused broadcast-apply barrier (engine round, m=16, d=1e5) ---
    // After: one pool section per round — the Δṽ broadcast apply rides
    // the next round's local-step dispatch. Before (emulated): a second
    // pool barrier per round, forced by flushing the pending broadcast
    // through sync_workers() after every round — the pre-engine round
    // applied the broadcast before returning, paying that extra
    // synchronization (and, worse, applying serially on the
    // coordinator thread; the flush here is already machine-parallel,
    // so the measured gap under-states the old cost).
    {
        use dadm::comm::Cluster;
        let (n, d, machines) = (scaled_bench_n(8_000), 100_000usize, 16usize);
        let data = SyntheticSpec {
            name: "fused-round".into(),
            n,
            d,
            density: 0.0005,
            signal_density: 0.2,
            noise: 0.1,
            seed: 21,
        }
        .generate();
        let part = Partition::balanced(n, machines, 21);
        let build = || {
            let mut dadm = build_dadm(
                &data,
                &part,
                SmoothHinge::default(),
                ElasticNet::new(0.1),
                Zero,
                1e-4,
                ProxSdca,
                DadmOptions {
                    sp: 0.05,
                    cluster: Cluster::Threads,
                    cost: CostModel::free(),
                    sparse_comm: true,
                    ..Default::default()
                },
            );
            dadm.resync();
            dadm
        };
        let mut fused = build();
        let t_fused = time_it(2, 8, || {
            fused.round();
        });
        let mut two_barrier = build();
        let t_two = time_it(2, 8, || {
            two_barrier.round();
            two_barrier.sync_workers();
        });
        table.row(&[
            "dadm_round_fused_barrier".into(),
            format!("m={machines} d={d} sp=0.05 sparse"),
            fmt_secs(t_fused.median),
            format!(
                "{:.2}x vs two-barrier {}",
                t_two.median / t_fused.median,
                fmt_secs(t_two.median)
            ),
        ]);
    }

    // --- Global-step scratch workspace (alloc-free vs per-round Vecs) ---
    // Before: every round allocated ∇g*'s z, the prox output, a full
    // ṽ clone, and fresh broadcast index/value vectors. After: all five
    // live in persistent buffers (GlobalScratch / PendingBroadcast).
    {
        let d = 100_000usize;
        let reg = ElasticNet::new(0.1);
        let h = Zero;
        let mut rng = Rng::new(31);
        let v: Vec<f64> = (0..d)
            .map(|_| if rng.bernoulli(0.01) { rng.normal() } else { 0.0 })
            .collect();
        // Independent sparse ṽ so the broadcast extraction actually
        // pushes entries (with h = 0, ṽ == v would make Δṽ empty).
        let v_tilde: Vec<f64> = (0..d)
            .map(|_| if rng.bernoulli(0.01) { rng.normal() } else { 0.0 })
            .collect();
        let t_alloc = time_it(2, 10, || {
            // The pre-engine allocating global step, verbatim shape:
            // z = ∇g*(v); w = prox_h(z); clone old ṽ; extract broadcast.
            let z = reg.grad_conj(&v);
            let w = h.prox(&z, 1.0);
            let v_tilde_old = v_tilde.clone();
            let mut idx: Vec<u32> = Vec::new();
            let mut val: Vec<f64> = Vec::new();
            for (j, (&vj, &vo)) in v.iter().zip(&v_tilde_old).enumerate() {
                let nv = vj - (z[j] - w[j]);
                if nv - vo != 0.0 {
                    idx.push(j as u32);
                    val.push(nv);
                }
            }
            std::hint::black_box((z, w, v_tilde_old, idx, val));
        });
        let mut z_buf = vec![0.0; d];
        let mut w_buf = vec![0.0; d];
        let mut old_buf = vec![0.0; d];
        let mut idx_buf: Vec<u32> = Vec::new();
        let mut val_buf: Vec<f64> = Vec::new();
        let t_scratch = time_it(2, 10, || {
            old_buf.copy_from_slice(&v_tilde);
            reg.grad_conj_into(&v, &mut z_buf);
            h.prox_into(&z_buf, 1.0, &mut w_buf);
            idx_buf.clear();
            val_buf.clear();
            for (j, (&vj, &vo)) in v.iter().zip(&old_buf).enumerate() {
                let nv = vj - (z_buf[j] - w_buf[j]);
                if nv - vo != 0.0 {
                    idx_buf.push(j as u32);
                    val_buf.push(nv);
                }
            }
            std::hint::black_box((&z_buf, &w_buf, &old_buf, &idx_buf, &val_buf));
        });
        table.row(&[
            "global_step_scratch".into(),
            format!("d={d} elastic-net + h-prox + bcast extract"),
            fmt_secs(t_scratch.median),
            format!(
                "{:.2}x vs allocating {}",
                t_alloc.median / t_scratch.median,
                fmt_secs(t_alloc.median)
            ),
        ]);
    }

    // --- Unrolled sparse-row dot (4-accumulator ILP gather) ---
    // Long rcv1-style rows: the serial single-accumulator fold chains
    // every FP add behind the previous one; four independent streams
    // overlap the gather loads with the adds. The reference below is the
    // pre-unroll loop, verbatim.
    {
        let d = 1 << 17;
        let nnz = scaled_bench_n(20_000);
        let mut rng = Rng::new(0xD07);
        let mut cols = rng.sample_indices(d, nnz);
        cols.sort_unstable();
        let rows: Vec<Vec<(u32, f64)>> = vec![cols
            .iter()
            .map(|&j| (j as u32, rng.normal()))
            .collect()];
        let m = dadm::data::SparseMatrix::from_rows(rows, d);
        let w: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        let row = m.row(0);
        let reps = 200usize;
        let t_unrolled = time_it(2, 10, || {
            let mut acc = 0.0;
            for _ in 0..reps {
                acc += row.dot(&w);
            }
            std::hint::black_box(acc);
        });
        let serial_dot = |r: &dadm::data::SparseRow<'_>, w: &[f64]| -> f64 {
            let mut acc = 0.0;
            for (&j, &v) in r.indices.iter().zip(r.values) {
                acc += v * w[j as usize];
            }
            acc
        };
        let t_serial = time_it(2, 10, || {
            let mut acc = 0.0;
            for _ in 0..reps {
                acc += serial_dot(&row, &w);
            }
            std::hint::black_box(acc);
        });
        table.row(&[
            "sparse_dot_unrolled".into(),
            format!("nnz={nnz} d={d}"),
            fmt_secs(t_unrolled.median / reps as f64),
            format!(
                "{:.2}x vs serial fold, {:.0}M nnz/s",
                t_serial.median / t_unrolled.median,
                (reps * nnz) as f64 / t_unrolled.median / 1e6
            ),
        ]);
    }

    // --- Hierarchical intra-machine parallelism (DESIGN.md §10) ---
    // A four-machine pool round at d = 1e5 sparse: with T = 1 each
    // machine is one thread (the pre-hierarchy behavior); with T = 4 the
    // same machines run four concurrent sub-shard solvers each and merge
    // sub-deltas machine-locally, so the round saturates 16 threads.
    {
        use dadm::comm::Cluster;
        let (n, d, machines) = (scaled_bench_n(16_000), 100_000usize, 4usize);
        let data = SyntheticSpec {
            name: "local-threads".into(),
            n,
            d,
            density: 0.0005,
            signal_density: 0.2,
            noise: 0.1,
            seed: 23,
        }
        .generate();
        let part = Partition::balanced(n, machines, 23);
        let build = |t: usize| {
            let mut dadm = build_dadm(
                &data,
                &part,
                SmoothHinge::default(),
                ElasticNet::new(0.1),
                Zero,
                1e-4,
                ProxSdca,
                DadmOptions {
                    sp: 0.2,
                    cluster: Cluster::Threads,
                    cost: CostModel::free(),
                    sparse_comm: true,
                    local_threads: t,
                    ..Default::default()
                },
            );
            dadm.resync();
            dadm
        };
        let mut t1_solver = build(1);
        let t_one = time_it(2, 8, || {
            t1_solver.round();
        });
        let mut t4_solver = build(4);
        let t_four = time_it(2, 8, || {
            t4_solver.round();
        });
        for (label, timing) in [("T=1", &t_one), ("T=4", &t_four)] {
            table.row(&[
                "dadm_round_local_threads".into(),
                format!("m={machines} d={d} sp=0.2 {label}"),
                fmt_secs(timing.median),
                if label == "T=4" {
                    format!("{:.2}x vs T=1", t_one.median / t_four.median)
                } else {
                    "baseline".into()
                },
            ]);
        }

        // The eval leg (full-pass duality gap: primal + dual sums) on the
        // same problems — serial per machine at T=1, sub-shard-parallel
        // at T=4.
        let t_eval_one = time_it(1, 5, || {
            std::hint::black_box(t1_solver.gap());
        });
        let t_eval_four = time_it(1, 5, || {
            std::hint::black_box(t4_solver.gap());
        });
        for (label, timing) in [("T=1", &t_eval_one), ("T=4", &t_eval_four)] {
            table.row(&[
                "eval_leg_parallel".into(),
                format!("m={machines} d={d} {label}"),
                fmt_secs(timing.median),
                if label == "T=4" {
                    format!("{:.2}x vs T=1", t_eval_one.median / t_eval_four.median)
                } else {
                    "baseline".into()
                },
            ]);
        }
    }

    // --- Fused gap telemetry vs separate eval barriers (DESIGN.md §11) ---
    // A --gap-every 1 round used to pay three pool barriers (fused local
    // step, primal loss pass, dual conj pass); the fused protocol rides
    // everything on the local-step leg plus an O(1) conjugate read.
    {
        use dadm::comm::Cluster;
        let (n, d, machines) = (scaled_bench_n(8_000), 100_000usize, 8usize);
        let data = SyntheticSpec {
            name: "gap-fused".into(),
            n,
            d,
            density: 0.0005,
            signal_density: 0.2,
            noise: 0.1,
            seed: 27,
        }
        .generate();
        let part = Partition::balanced(n, machines, 27);
        let build = || {
            let mut dadm = build_dadm(
                &data,
                &part,
                SmoothHinge::default(),
                ElasticNet::new(0.1),
                Zero,
                1e-4,
                ProxSdca,
                DadmOptions {
                    sp: 0.05,
                    cluster: Cluster::Threads,
                    cost: CostModel::free(),
                    sparse_comm: true,
                    ..Default::default()
                },
            );
            dadm.resync();
            let _ = dadm.gap(); // arm the running conjugate sums
            dadm
        };
        let mut fused = build();
        let t_fused = time_it(2, 8, || {
            // One barrier: round + entering loss sum + post-step conj.
            let _ = fused.round_fused(true, true);
        });
        let mut separate = build();
        let t_sep = time_it(2, 8, || {
            separate.round();
            std::hint::black_box(separate.primal());
            std::hint::black_box(separate.dual());
        });
        table.row(&[
            "gap_eval_fused".into(),
            format!("m={machines} d={d} sp=0.05 sparse"),
            fmt_secs(t_fused.median),
            format!(
                "{:.2}x vs three-barrier {}",
                t_sep.median / t_fused.median,
                fmt_secs(t_sep.median)
            ),
        ]);
    }

    // --- Incremental dual conjugate sum vs exact O(n) resummation ---
    // The dual side of a gap eval reads a held scalar (maintained in
    // O(1) per touched coordinate); the exact pass remains only as the
    // periodic drift-bounding resummation.
    {
        let n = scaled_bench_n(20_000);
        let data = SyntheticSpec {
            name: "conj-incr".into(),
            n,
            d: 2048,
            density: 0.02,
            signal_density: 0.2,
            noise: 0.1,
            seed: 29,
        }
        .generate();
        let part = Partition::balanced(n, 1, 1);
        let mut ws = WorkerState::from_partition(&data, &part, 0);
        let loss = SmoothHinge::default();
        let reg = ElasticNet::new(0.1);
        let lambda_n_l = 1e-4 * n as f64;
        let mut rng = Rng::new(30);
        let _ = ws.conj_running(&loss); // arm the running sum
        for _ in 0..5 {
            let batch = rng.sample_indices(n, 256.min(n));
            let _ = ProxSdca.local_step(&mut ws, &batch, &loss, &reg, lambda_n_l, &mut rng);
        }
        let t_exact = time_it(2, 10, || {
            std::hint::black_box(ws.dual_conj_sum(&loss));
        });
        let t_incr = time_it(2, 10, || {
            std::hint::black_box(ws.conj_running(&loss));
        });
        table.row(&[
            "conj_sum_incremental".into(),
            format!("n={n} exact resum pass"),
            fmt_secs(t_exact.median),
            format!(
                "{:.0}x vs O(1) held read {}",
                t_exact.median / t_incr.median.max(1e-9),
                fmt_secs(t_incr.median)
            ),
        ]);
    }

    // --- LIBSVM text parse vs mmap cache open (out-of-core loader, §15) ---
    {
        use dadm::data::{cache, libsvm, CsrCache};
        let n = scaled_bench_n(20_000);
        let data = SyntheticSpec {
            name: "perf-cache".into(),
            n,
            d: 512,
            density: 0.05,
            signal_density: 0.2,
            noise: 0.1,
            seed: 31,
        }
        .generate();
        let dir = std::env::temp_dir();
        let text = dir.join(format!("dadm_perf_cache_{}.libsvm", std::process::id()));
        let bin = dir.join(format!("dadm_perf_cache_{}.bin", std::process::id()));
        let mut buf = Vec::new();
        libsvm::write(&data, &mut buf).expect("serialize libsvm");
        std::fs::write(&text, &buf).expect("write text fixture");
        let t_parse = time_it(1, 5, || {
            std::hint::black_box(libsvm::load(&text).expect("parse").n());
        });
        cache::compile(&text, &bin).expect("compile cache");
        // Cache open is O(1) + one O(n) row-offset scan — no float
        // parsing, no per-row allocation — so it must come in far under
        // the text parse (the ≥ 50x acceptance pin of ISSUE 9).
        let t_open = time_it(2, 20, || {
            std::hint::black_box(CsrCache::open(&bin).expect("open").rows());
        });
        table.row(&[
            "libsvm_parse_vs_cache_open".into(),
            format!("parse n={n} d=512"),
            fmt_secs(t_parse.median),
            String::new(),
        ]);
        table.row(&[
            "libsvm_parse_vs_cache_open".into(),
            format!("mmap open n={n} d=512"),
            fmt_secs(t_open.median),
            format!(
                "{:.0}x faster than parse",
                t_parse.median / t_open.median.max(1e-9)
            ),
        ]);

        // A full ProxSDCA epoch over zero-copy mapped rows (contiguous
        // partition → `slice_rows` fast path): the hot loop reads
        // indices/values straight out of the mapping.
        let cache = CsrCache::open(&bin).expect("open cache");
        let mapped = cache.dataset().expect("decode cache");
        let part = Partition::contiguous(n, 1);
        let mut ws = WorkerState::from_partition(&mapped, &part, 0);
        let loss = SmoothHinge::default();
        let reg = ElasticNet::new(0.1);
        let lambda_n_l = 1e-4 * n as f64;
        let batch: Vec<usize> = (0..n).collect();
        let mut rng = Rng::new(32);
        let t = time_it(1, 5, || {
            let dv = ProxSdca
                .local_step(&mut ws, &batch, &loss, &reg, lambda_n_l, &mut rng)
                .into_dense();
            ws.apply_global(&dv, &reg);
        });
        table.row(&[
            "epoch_over_mmap".into(),
            format!("n={n} d=512 dens=0.05"),
            fmt_secs(t.median),
            format!("{:.2}M coord/s", n as f64 / t.median / 1e6),
        ]);
        let _ = std::fs::remove_file(&text);
        let _ = std::fs::remove_file(&bin);
    }

    // --- Straggler repair: nnz-balanced cuts on a skewed set (§16) ---
    // A head block of dense rows hoards the stored non-zeros, so under
    // row-balanced contiguous cuts one machine's local step dominates
    // every round (the straggler). The nnz-balanced cut equalizes
    // per-shard nnz, so the same 8-machine pool round must come in
    // well under the row-cut time (the ≥ 25% acceptance pin).
    {
        use dadm::comm::Cluster;
        use dadm::data::SparseMatrix;
        let (n, d, machines) = (scaled_bench_n(16_000), 4096usize, 8usize);
        let head = n / 10; // dense head: ~10% of rows, ~90% of nnz
        let mut rng = Rng::new(33);
        let mut rows = Vec::with_capacity(n);
        let mut y = Vec::with_capacity(n);
        for i in 0..n {
            let nnz = if i < head { d / 5 } else { d / 500 };
            let mut row: Vec<(u32, f64)> = (0..nnz)
                .map(|_| (rng.below(d) as u32, rng.uniform(-1.0, 1.0)))
                .collect();
            row.sort_unstable_by_key(|&(j, _)| j);
            row.dedup_by_key(|&mut (j, _)| j);
            rows.push(row);
            y.push(if rng.next_f64() < 0.5 { -1.0 } else { 1.0 });
        }
        let data = Dataset {
            x: SparseMatrix::from_rows(rows, d),
            y,
            name: "perf-skewed".into(),
        };
        let parts = [
            ("balance=rows", Partition::contiguous(n, machines)),
            (
                "balance=nnz",
                Partition::contiguous_nnz(&data.x.nnz_prefix(), machines),
            ),
        ];
        let mut medians = Vec::new();
        for (label, part) in &parts {
            let mut dadm = build_dadm(
                &data,
                part,
                SmoothHinge::default(),
                ElasticNet::new(0.1),
                Zero,
                1e-4,
                ProxSdca,
                DadmOptions {
                    sp: 0.5,
                    cluster: Cluster::Threads,
                    cost: CostModel::free(),
                    sparse_comm: true,
                    ..Default::default()
                },
            );
            dadm.resync();
            let t = time_it(2, 8, || {
                dadm.round();
            });
            medians.push((*label, t.median));
        }
        let rows_median = medians[0].1;
        for (label, median) in &medians {
            table.row(&[
                "dadm_round_skewed_balance".into(),
                format!("m={machines} skewed {label}"),
                fmt_secs(*median),
                if *label == "balance=nnz" {
                    format!(
                        "{:.2}x vs rows ({:.0}% cut)",
                        rows_median / median,
                        100.0 * (1.0 - median / rows_median)
                    )
                } else {
                    "baseline".into()
                },
            ]);
        }
    }

    // --- Work-stealing pool under skewed job durations (§16) ---
    // 16 jobs, one 8x heavier than the rest, on the shared pool: with
    // stealing, idle threads drain the uniform tail while one thread
    // owns the heavy job, so wall time approaches
    // max(heavy, total/threads) instead of serializing behind a fixed
    // job-to-thread assignment.
    {
        use dadm::comm::pool::WorkerPool;
        let jobs = 16usize;
        let heavy_reps = 400_000u64;
        let light_reps = heavy_reps / 8;
        let spin = |reps: u64| {
            let mut acc = 0.0f64;
            for i in 0..reps {
                acc += (i as f64).sqrt();
            }
            std::hint::black_box(acc)
        };
        let pool = WorkerPool::global();
        let mut states: Vec<u64> = (0..jobs)
            .map(|k| if k == 0 { heavy_reps } else { light_reps })
            .collect();
        let t = time_it(2, 10, || {
            let run = pool.run(&mut states, |_, reps| spin(*reps));
            std::hint::black_box(run.results.len());
        });
        let total_reps = heavy_reps + light_reps * (jobs as u64 - 1);
        table.row(&[
            "pool_work_stealing".into(),
            format!("jobs={jobs} skew=8x"),
            fmt_secs(t.median),
            format!(
                "{:.0}M reps/s on {} threads",
                total_reps as f64 / t.median / 1e6,
                pool.workers()
            ),
        ]);
    }

    // --- PJRT execute latency (requires artifacts) ---
    {
        use dadm::runtime::XlaLocalStep;
        let loss = SmoothHinge::default();
        match XlaLocalStep::new(loss.name(), 128, 256, 1.0) {
            Ok(step) => {
                let n = 4_096;
                let data = SyntheticSpec {
                    name: "perf-xla".into(),
                    n,
                    d: 256,
                    density: 0.1,
                    signal_density: 0.2,
                    noise: 0.1,
                    seed: 5,
                }
                .generate();
                let part = Partition::balanced(n, 1, 1);
                let mut ws = WorkerState::from_partition(&data, &part, 0);
                let reg = ElasticNet::new(0.1);
                let batch: Vec<usize> = (0..128).collect();
                let mut rng = Rng::new(6);
                let t = time_it(2, 10, || {
                    let _ = step.local_step(&mut ws, &batch, &loss, &reg, 0.4, &mut rng);
                });
                table.row(&[
                    "xla_local_step".into(),
                    "M=128 d=256".into(),
                    fmt_secs(t.median),
                    format!("{:.0}k coord/s", 128.0 / t.median / 1e3),
                ]);
            }
            Err(_) => {
                table.row(&[
                    "xla_local_step".into(),
                    "M=128 d=256".into(),
                    "skipped".into(),
                    "run `make artifacts`".into(),
                ]);
            }
        }
    }

    table.finish();
}
